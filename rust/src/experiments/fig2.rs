//! Fig 2 (and Table 1): cycles per element update of the basic sparse
//! vector operations at strides k = 1 (dense packing), k = 8 (one entry
//! per cache line) and k = 530 (one entry per memory page, chosen odd to
//! avoid cache trashing), on all three simulated machines plus real host
//! wall-clock.
//!
//! Paper shapes to reproduce:
//! - indirect addressing (IS) costs ~50% over direct constant stride (CS)
//!   at dense packing (extra 4 B/iter for the index vector);
//! - k = 8 drops performance by ~the cache-line factor (whole line per
//!   useful element);
//! - k = 530 adds a TLB penalty on top.

use crate::kernels::{IndexPattern, MicroBuffers, MicroOp, OpKind};
use crate::simulator::{simulate_microbench, SimOptions};
use crate::util::bench;
use crate::util::report::{f, Table};

use super::ExpOptions;

/// Ops of Table 1 for a given stride class.
fn ops_for(k: usize) -> Vec<MicroOp> {
    if k == 1 {
        vec![
            MicroOp { kind: OpKind::Add, pattern: IndexPattern::Dense },
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Dense },
            MicroOp { kind: OpKind::Add, pattern: IndexPattern::IndexedStride(1) },
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::IndexedStride(1) },
            MicroOp { kind: OpKind::Add, pattern: IndexPattern::Geometric { mean: 1.0 } },
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Geometric { mean: 1.0 } },
        ]
    } else {
        vec![
            MicroOp { kind: OpKind::Add, pattern: IndexPattern::ConstStride(k) },
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::ConstStride(k) },
            MicroOp { kind: OpKind::Add, pattern: IndexPattern::IndexedStride(k) },
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::IndexedStride(k) },
            MicroOp { kind: OpKind::Add, pattern: IndexPattern::Geometric { mean: k as f64 } },
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Geometric { mean: k as f64 } },
        ]
    }
}

pub fn run(opts: &ExpOptions) -> Vec<Table> {
    let n = opts.micro_iters();
    let sim_opts = SimOptions { warmup: false, ..Default::default() };
    let mut tables = Vec::new();

    for &k in &[1usize, 8, 530] {
        let title = format!(
            "Fig 2 — basic sparse ops, stride k={k} ({}): cycles per update",
            match k {
                1 => "dense packing",
                8 => "one entry per cache line",
                _ => "one entry per page",
            }
        );
        let mut header: Vec<String> = vec!["op".into()];
        header.extend(opts.machines.iter().map(|m| format!("{} (sim)", m.name)));
        header.push("host ns/upd".into());
        let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&title, &href);

        // B array sized so gathers exceed every LLC.
        let b_len = (n * k.max(1) * 2).max(4 << 20);
        for op in ops_for(k) {
            let mut row = vec![op.name()];
            for m in &opts.machines {
                let r = simulate_microbench(m, op, n, b_len, &sim_opts, 42);
                row.push(f(r.cycles_per_update));
            }
            // Host wall-clock (ns/update; the host CPU is not one of the
            // paper's machines — shape comparison only).
            let bufs = MicroBuffers::new(op, n, b_len, 42);
            let b = if opts.quick { bench::Bench::quick() } else { bench::default_bench() };
            let res = b.run(&op.name(), n as u64, op.flops_per_iter() * n as u64, || bufs.run());
            row.push(f(res.ns_per_item()));
            t.row(row);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::MachineSpec;

    fn cycles(m: &MachineSpec, op: MicroOp, n: usize, blen: usize) -> f64 {
        simulate_microbench(m, op, n, blen, &SimOptions { warmup: false, ..Default::default() }, 42).cycles_per_update
    }

    #[test]
    fn indirect_overhead_is_moderate_at_unit_stride() {
        // ISADD(k=1) vs dense ADD: the index array adds 4 B to 8 B per
        // iteration -> ~50% more traffic (paper: "overhead of around 50%
        // for ISADD").
        let m = MachineSpec::woodcrest();
        let n = 50_000;
        let blen = 4 << 20;
        let dense = cycles(&m, MicroOp { kind: OpKind::Add, pattern: IndexPattern::Dense }, n, blen);
        let is1 = cycles(
            &m,
            MicroOp { kind: OpKind::Add, pattern: IndexPattern::IndexedStride(1) },
            n,
            blen,
        );
        let ratio = is1 / dense;
        assert!(
            (1.2..2.2).contains(&ratio),
            "ISADD/PDADD ratio {ratio:.2}, expected ~1.5"
        );
    }

    #[test]
    fn cacheline_stride_is_much_slower() {
        let m = MachineSpec::nehalem();
        let n = 50_000;
        let k1 = cycles(
            &m,
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::IndexedStride(1) },
            n,
            4 << 20,
        );
        let k8 = cycles(
            &m,
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::IndexedStride(8) },
            n,
            8 << 20,
        );
        assert!(k8 > 3.0 * k1, "k=8 {k8:.1} should be >> k=1 {k1:.1}");
    }

    #[test]
    fn page_stride_adds_tlb_penalty() {
        let m = MachineSpec::woodcrest();
        let n = 30_000;
        let k512 = cycles(
            &m,
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::IndexedStride(512) },
            n,
            64 << 20,
        );
        let k530 = cycles(
            &m,
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::IndexedStride(530) },
            n,
            64 << 20,
        );
        // 530 elements * 8 B > page: every access a new page -> TLB bound;
        // 512 is page-aligned power of two (cache trashing) — both slow,
        // and much slower than a cache-line stride.
        let k8 = cycles(
            &m,
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::IndexedStride(8) },
            n,
            8 << 20,
        );
        assert!(k530 > 1.5 * k8, "k=530 {k530:.1} vs k=8 {k8:.1}");
        assert!(k512 > 1.5 * k8, "k=512 {k512:.1} vs k=8 {k8:.1}");
    }

    #[test]
    fn driver_emits_three_tables() {
        let opts = ExpOptions { quick: true, ..Default::default() };
        let tables = run(&opts);
        assert_eq!(tables.len(), 3);
        for t in &tables {
            assert_eq!(t.rows.len(), 6);
        }
    }
}
