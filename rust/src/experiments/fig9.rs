//! Fig 9 — scheduling policy, chunk size and block size interplay for
//! 2×4 threads on Nehalem.
//!
//! Paper shapes: static scheduling with the CRS format is best overall;
//! chunks smaller than a memory page randomize first-touch placement and
//! are hazardous; dynamic/guided scheduling disrupts NUMA locality; large
//! blocks × large chunks underutilize threads (too few chunks).

use crate::matrix::{Crs, Scheme};
use crate::sched::Schedule;
use crate::simulator::{simulate_spmv_plan, MachineSpec, Placement, SimOptions};
use crate::spmv::SpmvHandle;
use crate::util::report::{f, Table};

use super::{fixed_handle, ExpOptions};

pub fn chunks(quick: bool) -> Vec<usize> {
    if quick {
        vec![16, 1024]
    } else {
        vec![16, 128, 512, 2048, 8192, 32768]
    }
}

/// Simulate through the shared plan/execute API (2 sockets fully
/// populated): schedule × chunk decisions live in the handle's plan.
fn mflops(m: &MachineSpec, handle: &SpmvHandle, schedule: Schedule) -> f64 {
    let tps = m.cores_per_socket;
    let c = handle.replanned(schedule, tps * 2).expect("native handles replan");
    simulate_spmv_plan(
        m,
        c.kernel().expect("native backend has a kernel"),
        c.plan().expect("native backend has a plan"),
        tps,
        2,
        Placement::FirstTouchStatic,
        &SimOptions::default(),
    )
    .mflops
}

pub fn run(opts: &ExpOptions) -> Vec<Table> {
    let coo = opts.test_matrix();
    let crs = Crs::from_coo(&coo);
    let m = MachineSpec::nehalem();
    let mut tables = Vec::new();
    let blocks: Vec<usize> = if opts.quick {
        vec![64]
    } else {
        vec![128, 1000, 8192, 65536]
    };

    // CRS: schedule × chunk.
    let ch = chunks(opts.quick);
    let mut header: Vec<String> = vec!["schedule".into()];
    header.extend(ch.iter().map(|c| format!("chunk {c}")));
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Fig 9 — CRS on Nehalem 2x4 threads: MFlop/s by schedule and chunk",
        &href,
    );
    let k_crs = fixed_handle(&crs, Scheme::Crs);
    let default = mflops(&m, &k_crs, Schedule::Static { chunk: None });
    t.row({
        let mut r = vec!["static(default)".to_string()];
        r.extend(std::iter::repeat_n(f(default), ch.len()));
        r
    });
    for (name, mk) in [
        ("static", Box::new(|c: usize| Schedule::Static { chunk: Some(c) }) as Box<dyn Fn(usize) -> Schedule>),
        ("dynamic", Box::new(|c: usize| Schedule::Dynamic { chunk: c })),
        ("guided", Box::new(|c: usize| Schedule::Guided { min_chunk: c })),
    ] {
        let mut row = vec![name.to_string()];
        for &c in &ch {
            row.push(f(mflops(&m, &k_crs, mk(c))));
        }
        t.row(row);
    }
    tables.push(t);

    // Blocked JDS flavors: block × chunk under static scheduling (the
    // paper's per-scheme heatmap panels).
    for scheme_name in ["NBJDS", "RBJDS", "SOJDS"] {
        let mut header: Vec<String> = vec!["block".into()];
        header.extend(ch.iter().map(|c| format!("chunk {c}")));
        header.push("static(default)".into());
        let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!("Fig 9 — {scheme_name} on Nehalem 2x4 threads: MFlop/s by block and static chunk"),
            &href,
        );
        for &b in &blocks {
            let scheme = match scheme_name {
                "NBJDS" => Scheme::NbJds { block: b },
                "RBJDS" => Scheme::RbJds { block: b },
                _ => Scheme::SoJds { block: b },
            };
            let k = fixed_handle(&crs, scheme);
            let mut row = vec![b.to_string()];
            for &c in &ch {
                row.push(f(mflops(&m, &k, Schedule::Static { chunk: Some(c) })));
            }
            row.push(f(mflops(&m, &k, Schedule::Static { chunk: None })));
            t.row(row);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use std::sync::OnceLock;

    fn medium_crs() -> &'static Crs {
        static CRS: OnceLock<Crs> = OnceLock::new();
        CRS.get_or_init(|| {
            Crs::from_coo(&gen::holstein_hubbard(&gen::HolsteinHubbardParams {
                max_phonons: 4,
                ..gen::HolsteinHubbardParams::paper()
            }))
        })
    }

    #[test]
    fn static_default_beats_dynamic_small_chunks() {
        // Dynamic scheduling with small chunks disrupts NUMA locality.
        let m = MachineSpec::nehalem();
        let k = fixed_handle(medium_crs(), Scheme::Crs);
        let stat = mflops(&m, &k, Schedule::Static { chunk: None });
        let dyn_small = mflops(&m, &k, Schedule::Dynamic { chunk: 16 });
        assert!(
            stat > 1.1 * dyn_small,
            "static {stat:.0} must beat dynamic,16 {dyn_small:.0}"
        );
    }

    #[test]
    fn sub_page_static_chunks_are_hazardous() {
        // Chunks far below a page (512 rows x 8 B = 4 KiB) randomize
        // placement: static,16 must trail static,{>=512}.
        let m = MachineSpec::nehalem();
        let k = fixed_handle(medium_crs(), Scheme::Crs);
        let tiny = mflops(&m, &k, Schedule::Static { chunk: Some(16) });
        let page = mflops(&m, &k, Schedule::Static { chunk: Some(4096) });
        assert!(
            page > 1.1 * tiny,
            "page-sized chunks {page:.0} must beat sub-page {tiny:.0}"
        );
    }

    #[test]
    fn driver_quick() {
        let opts = ExpOptions { quick: true, ..Default::default() };
        let tables = run(&opts);
        assert_eq!(tables.len(), 4);
    }
}
