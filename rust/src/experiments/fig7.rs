//! Fig 7 — block-size dependence of serial SpMVM performance for the
//! blocked JDS schemes (NBJDS, RBJDS, SOJDS), with CRS / JDS / NUJDS as
//! horizontal reference lines.
//!
//! Paper shapes: each blocked scheme has an optimal block-size plateau;
//! RBJDS and SOJDS have a *wider* range of good block sizes than NBJDS
//! (their storage stays contiguous under blocking); at the optimum none
//! of them beats CRS.

use crate::kernels::SpmvKernel;
use crate::matrix::{Crs, Scheme};
use crate::sched::Schedule;
use crate::simulator::{simulate_spmv, MachineSpec, Placement, SimOptions};
use crate::util::report::{f, Table};

use super::ExpOptions;

pub fn blocks(quick: bool, nrows: usize) -> Vec<usize> {
    let mut v = if quick {
        vec![8, 64, 512]
    } else {
        vec![16, 64, 256, 1000, 4096, 16384, 65536, 262144]
    };
    v.retain(|&b| b <= nrows.max(16));
    v.push(nrows); // block = N  ==  plain JDS limit
    v
}

fn serial_mflops(m: &MachineSpec, k: &SpmvKernel) -> f64 {
    simulate_spmv(
        m,
        k,
        1,
        1,
        Schedule::Static { chunk: None },
        Placement::FirstTouchStatic,
        &SimOptions::default(),
    )
    .mflops
}

pub fn run(opts: &ExpOptions) -> Vec<Table> {
    let coo = opts.test_matrix();
    let crs = Crs::from_coo(&coo);
    let machines: Vec<&MachineSpec> = opts
        .machines
        .iter()
        .filter(|m| m.name != "Shanghai" || opts.full) // paper: Shanghai ~ Nehalem
        .collect();
    let mut tables = Vec::new();

    for m in machines {
        let mut t = Table::new(
            &format!("Fig 7 — block-size dependence on {} (serial MFlop/s)", m.name),
            &["block", "NBJDS", "RBJDS", "SOJDS"],
        );
        for &b in &blocks(opts.quick, crs.nrows) {
            let nb = SpmvKernel::build_from_crs(&crs, Scheme::NbJds { block: b });
            let rb = SpmvKernel::build_from_crs(&crs, Scheme::RbJds { block: b });
            let so = SpmvKernel::build_from_crs(&crs, Scheme::SoJds { block: b });
            t.row(vec![
                b.to_string(),
                f(serial_mflops(m, &nb)),
                f(serial_mflops(m, &rb)),
                f(serial_mflops(m, &so)),
            ]);
        }
        // Reference lines.
        let mut t2 = Table::new(
            &format!("Fig 7 — unblocked references on {}", m.name),
            &["scheme", "MFlop/s"],
        );
        for s in [Scheme::Crs, Scheme::Jds, Scheme::NuJds { unroll: 2 }] {
            let k = SpmvKernel::build_from_crs(&crs, s);
            t2.row(vec![s.name(), f(serial_mflops(m, &k))]);
        }
        tables.push(t);
        tables.push(t2);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;

    #[test]
    fn optimal_block_beats_extremes_for_nbjds() {
        // A mid-size block must beat both block=tiny (loop overhead) and
        // block=N (plain JDS: result vector streamed once per diagonal).
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams {
            max_phonons: 3,
            ..gen::HolsteinHubbardParams::paper()
        });
        let crs = Crs::from_coo(&coo);
        let m = MachineSpec::nehalem();
        let perf = |b: usize| {
            let k = SpmvKernel::build_from_crs(&crs, Scheme::NbJds { block: b });
            serial_mflops(&m, &k)
        };
        let tiny = perf(4);
        let mid = perf(1000);
        let huge = perf(crs.nrows);
        assert!(mid > tiny, "block 1000 ({mid:.0}) must beat block 4 ({tiny:.0})");
        assert!(mid > huge, "block 1000 ({mid:.0}) must beat block N ({huge:.0})");
    }

    #[test]
    fn rbjds_tolerates_small_blocks_better_than_nbjds() {
        // RBJDS keeps val/col contiguous even for small blocks, so its
        // small-block penalty must be smaller than NBJDS's (wider
        // plateau, Fig 7).
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams {
            max_phonons: 3,
            ..gen::HolsteinHubbardParams::paper()
        });
        let crs = Crs::from_coo(&coo);
        let m = MachineSpec::nehalem();
        let perf = |s: Scheme| serial_mflops(&m, &SpmvKernel::build_from_crs(&crs, s));
        let nb_small = perf(Scheme::NbJds { block: 16 });
        let nb_best = perf(Scheme::NbJds { block: 1000 });
        let rb_small = perf(Scheme::RbJds { block: 16 });
        let rb_best = perf(Scheme::RbJds { block: 1000 });
        let nb_drop = nb_best / nb_small;
        let rb_drop = rb_best / rb_small;
        assert!(
            rb_drop < nb_drop,
            "RBJDS small-block drop {rb_drop:.2} must be smaller than NBJDS {nb_drop:.2}"
        );
    }

    #[test]
    fn driver_quick() {
        let opts = ExpOptions { quick: true, ..Default::default() };
        let tables = run(&opts);
        assert!(tables.len() >= 4);
    }
}
