//! Experiment drivers regenerating every figure of the paper's
//! evaluation (see DESIGN.md §3 for the experiment index). Each driver
//! returns text tables whose rows/series mirror the paper's plots; the
//! CLI (`spmvperf experiment <id>`) prints them and can emit CSV, and the
//! `cargo bench` targets wrap the same drivers.

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;

use anyhow::Result;

use crate::gen::{self, HolsteinHubbardParams};
use crate::matrix::{Coo, Crs, Scheme};
use crate::sched::Schedule;
use crate::simulator::MachineSpec;
use crate::spmv::{BackendChoice, SpmvHandle};
use crate::tune::TuningPolicy;
use crate::util::report::Table;

/// A fixed-policy, single-thread native handle for one scheme — the
/// shared starting point of the fig 8/9 sweeps, which re-plan it per
/// data point via [`SpmvHandle::replanned`] (the kernel is shared,
/// nothing is re-tuned). The native backend is forced because these
/// drivers feed the handle's plan to the simulator.
pub(crate) fn fixed_handle(crs: &Crs, scheme: Scheme) -> SpmvHandle {
    SpmvHandle::builder_from_crs(crs)
        .policy(TuningPolicy::Fixed(scheme, Schedule::Static { chunk: None }))
        .backend(BackendChoice::Native)
        .threads(1)
        .build()
        .expect("fixed-policy native handle on a square matrix cannot fail")
}

/// Options shared by all experiment drivers.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Paper-scale sizes (N = 1,201,200 Hamiltonian etc.). Default uses
    /// scaled-down sizes that preserve the memory-bound regime.
    pub full: bool,
    /// Quick mode for CI/benches: tiny sizes, shapes only.
    pub quick: bool,
    /// Machines to include (defaults to the paper's x86 test bed).
    pub machines: Vec<MachineSpec>,
    /// Optional directory to drop one CSV per table into.
    pub csv_dir: Option<String>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            full: false,
            quick: false,
            machines: MachineSpec::all_x86(),
            csv_dir: None,
        }
    }
}

impl ExpOptions {
    /// Microbenchmark iteration count.
    pub fn micro_iters(&self) -> usize {
        if self.quick {
            5_000
        } else if self.full {
            1_000_000
        } else {
            60_000
        }
    }

    /// Parameters of the test matrix at the configured scale.
    pub fn test_params(&self) -> HolsteinHubbardParams {
        if self.full {
            HolsteinHubbardParams::paper() // N = 1,201,200
        } else if self.quick {
            HolsteinHubbardParams::tiny() // N = 540
        } else {
            // N = 369,600 (~5 M nnz): vectors exceed every simulated LLC,
            // like the paper's full-size Hamiltonian.
            HolsteinHubbardParams::medium()
        }
    }

    /// The paper's test matrix at the configured scale.
    pub fn test_matrix(&self) -> Coo {
        gen::holstein_hubbard(&self.test_params())
    }

    pub fn emit(&self, tables: &[Table]) -> Result<()> {
        for t in tables {
            t.print();
            if let Some(dir) = &self.csv_dir {
                let slug: String = t
                    .title
                    .chars()
                    .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
                    .collect::<String>()
                    .trim_matches('_')
                    .chars()
                    .take(60)
                    .collect();
                t.maybe_write_csv(Some(&format!("{dir}/{slug}.csv")))?;
            }
        }
        Ok(())
    }
}

/// Run an experiment by id ("fig2".."fig9", "all").
pub fn run(id: &str, opts: &ExpOptions) -> Result<()> {
    let ids: Vec<&str> = if id == "all" {
        vec!["fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9"]
    } else {
        vec![id]
    };
    for id in ids {
        eprintln!("== running experiment {id} ==");
        let tables = match id {
            "fig2" | "table1" => fig2::run(opts),
            "fig3" | "fig3a" | "fig3b" => fig3::run(opts),
            "fig4" => fig4::run(opts),
            "fig5" => fig5::run(opts),
            "fig6" | "fig6a" | "fig6b" => fig6::run(opts),
            "fig7" => fig7::run(opts),
            "fig8" => fig8::run(opts),
            "fig9" => fig9::run(opts),
            other => anyhow::bail!("unknown experiment '{other}'"),
        };
        opts.emit(&tables)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_options_pick_tiny_sizes() {
        let o = ExpOptions { quick: true, ..Default::default() };
        assert_eq!(o.micro_iters(), 5_000);
        assert_eq!(o.test_matrix().nrows, 540);
    }

    #[test]
    fn unknown_experiment_errors() {
        let o = ExpOptions { quick: true, ..Default::default() };
        assert!(run("fig99", &o).is_err());
    }
}
