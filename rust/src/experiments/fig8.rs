//! Fig 8 — OpenMP-parallel SpMVM: intra-socket and inter-socket scaling
//! on the three x86 machines, plus HLRB-II node scaling.
//!
//! Paper shapes: Nehalem/Shanghai scale up to ~3 threads/socket (then the
//! socket bandwidth saturates); a second Woodcrest thread per socket buys
//! nothing; the second Woodcrest socket buys only ~50% (FSB); ccNUMA
//! nodes scale ~2x across sockets with first-touch placement; Nehalem ≈
//! 2x Shanghai. HLRB-II: superlinear speedup once the per-thread
//! partition fits the aggregated L3, and NBJDS overtakes CRS at large
//! thread counts (short inner loops hurt the in-order Itanium2).

use crate::engine::affinity;
use crate::matrix::{Crs, Scheme};
use crate::sched::Schedule;
use crate::simulator::{simulate_spmv_plan, MachineSpec, Placement, SimOptions};
use crate::spmv::{BackendChoice, SpmvHandle};
use crate::tune::TuningPolicy;
use crate::util::report::{f, Table};
use crate::util::rng::Rng;

use super::{fixed_handle, ExpOptions};

/// Simulate through the shared plan/execute API: the same plan the
/// handle's host engine would run is handed to the machine model.
fn mflops(m: &MachineSpec, handle: &SpmvHandle, tps: usize, sockets: usize) -> f64 {
    let c = handle
        .replanned(Schedule::Static { chunk: None }, tps * sockets)
        .expect("native handles replan");
    simulate_spmv_plan(
        m,
        c.kernel().expect("native backend has a kernel"),
        c.plan().expect("native backend has a plan"),
        tps,
        sockets,
        Placement::FirstTouchStatic,
        &SimOptions::default(),
    )
    .mflops
}

pub fn run(opts: &ExpOptions) -> Vec<Table> {
    let coo = opts.test_matrix();
    let crs = Crs::from_coo(&coo);
    let block = if opts.quick { 64 } else { 1000 };
    let k_crs = fixed_handle(&crs, Scheme::Crs);
    let k_nb = fixed_handle(&crs, Scheme::NbJds { block });
    let mut tables = Vec::new();

    // --- x86 machines: threads/socket × sockets ---
    for m in &opts.machines {
        let mut t = Table::new(
            &format!(
                "Fig 8 — OpenMP scaling on {} (static, block {block}): MFlop/s",
                m.name
            ),
            &["sockets", "threads/socket", "CRS", "NBJDS", "CRS speedup"],
        );
        let base = mflops(m, &k_crs, 1, 1);
        let tps_list: Vec<usize> = (1..=m.cores_per_socket).collect();
        for sockets in 1..=m.sockets.min(2) {
            for &tps in &tps_list {
                let c = mflops(m, &k_crs, tps, sockets);
                let n = mflops(m, &k_nb, tps, sockets);
                t.row(vec![
                    sockets.to_string(),
                    tps.to_string(),
                    f(c),
                    f(n),
                    f(c / base),
                ]);
            }
        }
        tables.push(t);
    }

    // --- HLRB-II node scaling (2 threads per locality domain) ---
    let thread_counts: Vec<usize> = if opts.quick {
        vec![2, 8]
    } else {
        vec![2, 4, 8, 16, 32, 64, 128]
    };
    let mut t = Table::new(
        "Fig 8 (lower right) — HLRB-II node: measured vs ideal speedup",
        &["threads", "CRS MFlop/s", "NBJDS MFlop/s", "CRS speedup", "ideal"],
    );
    let domains_max = thread_counts.iter().max().copied().unwrap_or(2) / 2;
    let hlrb = MachineSpec::hlrb2(domains_max.max(1));
    let base_crs = mflops(&hlrb, &k_crs, 2, 1) / 2.0; // per-thread baseline
    for &threads in &thread_counts {
        let sockets = (threads / 2).max(1);
        let tps = if threads >= 2 { 2 } else { 1 };
        let c = mflops(&hlrb, &k_crs, tps, sockets);
        let n = mflops(&hlrb, &k_nb, tps, sockets);
        t.row(vec![
            threads.to_string(),
            f(c),
            f(n),
            f(c / base_crs),
            f(threads as f64),
        ]);
    }
    tables.push(t);

    // --- host replay: the same scaling story measured on the build
    // machine, with and without pinning + first-touch placement ---
    tables.push(host_pinning_scaling(opts, &crs));
    tables
}

/// Wall-clock MFlop/s of a CRS static-schedule handle on the host.
fn host_mflops(crs: &Crs, threads: usize, pinned: bool, reps: usize) -> f64 {
    let handle = SpmvHandle::builder_from_crs(crs)
        .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
        .backend(BackendChoice::Native)
        .threads(threads)
        .pinned(pinned)
        .build()
        .expect("fixed-policy native handle on a square matrix cannot fail");
    let n = crs.nrows;
    let mut x = vec![0.0; n];
    Rng::new(8).fill_f64(&mut x, -1.0, 1.0);
    let mut y = vec![0.0; n];
    // Measure through `handle.spmv`, whose kernel traffic runs on the
    // plan's own (first-touch placed) workspace; a caller-allocated
    // permuted workspace would bypass the placement being compared.
    handle.spmv(&x, &mut y); // warm caches + engine
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        handle.spmv(&x, &mut y);
        std::hint::black_box(y[0]);
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    2.0 * crs.nnz() as f64 / dt / 1e6
    // the handle drops here: a pinned engine restores the caller's
    // affinity, so the next (unpinned) measurement is not confined.
}

/// Fig 8, measured: OpenMP-style scaling on the actual host, pinned
/// (compact, first-touch) versus unpinned — the §5.2 claim the
/// simulator's `Placement::FirstTouchStatic` models, replayed for real.
fn host_pinning_scaling(opts: &ExpOptions, crs: &Crs) -> Table {
    let reps = if opts.quick { 3 } else { 10 };
    let host = affinity::n_cpus();
    let mut t = Table::new(
        &format!(
            "Fig 8 (host) — measured SpMV scaling, pinned vs unpinned ({host} CPUs, pinning {})",
            if affinity::pin_supported() { "supported" } else { "unsupported: no-op" }
        ),
        &["threads", "unpinned MFlop/s", "pinned MFlop/s", "pinned/unpinned"],
    );
    let counts: Vec<usize> = [1usize, 2, 4, 8].into_iter().filter(|&c| c <= host).collect();
    let counts = if counts.is_empty() { vec![1] } else { counts };
    for &nt in &counts {
        let unpinned = host_mflops(crs, nt, false, reps);
        let pinned = host_mflops(crs, nt, true, reps);
        t.row(vec![
            nt.to_string(),
            f(unpinned),
            f(pinned),
            f(pinned / unpinned.max(1e-9)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use std::sync::OnceLock;

    fn medium_crs() -> &'static Crs {
        static CRS: OnceLock<Crs> = OnceLock::new();
        CRS.get_or_init(|| {
            Crs::from_coo(&gen::holstein_hubbard(&gen::HolsteinHubbardParams {
                max_phonons: 4, // 84k rows, ~1.1M nnz
                ..gen::HolsteinHubbardParams::paper()
            }))
        })
    }

    #[test]
    fn nehalem_roughly_twice_shanghai_full_node() {
        let k = fixed_handle(medium_crs(), Scheme::Crs);
        let neh = mflops(&MachineSpec::nehalem(), &k, 4, 2);
        let sha = mflops(&MachineSpec::shanghai(), &k, 4, 2);
        let ratio = neh / sha;
        assert!(
            (1.4..2.6).contains(&ratio),
            "Nehalem/Shanghai full-node ratio {ratio:.2}, paper says ~2"
        );
    }

    #[test]
    fn woodcrest_second_thread_gains_nothing() {
        let k = fixed_handle(medium_crs(), Scheme::Crs);
        let m = MachineSpec::woodcrest();
        let one = mflops(&m, &k, 1, 1);
        let two = mflops(&m, &k, 2, 1);
        assert!(
            two < 1.15 * one,
            "Woodcrest 2nd thread: {one:.0} -> {two:.0} should be flat"
        );
    }

    #[test]
    fn woodcrest_second_socket_gains_about_half() {
        let k = fixed_handle(medium_crs(), Scheme::Crs);
        let m = MachineSpec::woodcrest();
        let one = mflops(&m, &k, 2, 1);
        let two = mflops(&m, &k, 2, 2);
        let gain = two / one;
        assert!(
            (1.2..1.8).contains(&gain),
            "Woodcrest socket scaling {gain:.2}, paper says ~1.5"
        );
    }

    #[test]
    fn hlrb2_superlinear_and_nbjds_wins_at_scale() {
        // With enough threads the matrix partitions fit the Itanium L3s:
        // superlinear CRS speedup; and NBJDS (long loops) must overtake
        // CRS (short loops, heavy in-order loop startup) at high counts.
        let k_crs = fixed_handle(medium_crs(), Scheme::Crs);
        let k_nb = fixed_handle(medium_crs(), Scheme::NbJds { block: 1000 });
        let m = MachineSpec::hlrb2(32);
        let base = mflops(&m, &k_crs, 2, 1);
        let crs64 = mflops(&m, &k_crs, 2, 32);
        let nb64 = mflops(&m, &k_nb, 2, 32);
        let speedup = crs64 / base * 2.0; // threads: 2 -> 64
        assert!(
            speedup > 32.0,
            "CRS speedup at 64 threads {speedup:.1} should be superlinear-ish (>32)"
        );
        assert!(
            nb64 > crs64,
            "NBJDS {nb64:.0} must dominate CRS {crs64:.0} at large thread counts"
        );
    }

    #[test]
    fn driver_quick() {
        let opts = ExpOptions { quick: true, ..Default::default() };
        let tables = run(&opts);
        assert_eq!(tables.len(), 5); // 3 machines + HLRB-II + host pinning
        assert!(tables[4].title.contains("pinned"));
    }

    #[test]
    fn host_scaling_measures_both_placements() {
        let crs = Crs::from_coo(&crate::gen::holstein_hubbard(
            &crate::gen::HolsteinHubbardParams::tiny(),
        ));
        let m = host_mflops(&crs, 2, true, 2);
        assert!(m > 0.0, "pinned host measurement must produce a throughput");
        let u = host_mflops(&crs, 2, false, 2);
        assert!(u > 0.0);
    }
}
