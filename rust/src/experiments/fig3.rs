//! Fig 3 — left: ISSCP/IRSCP performance vs input-vector stride
//! (power-of-two cache-trashing spikes; the small-k "bulge" from
//! spurious strided prefetches); right: prefetcher ablation on
//! Woodcrest (SP/AP on/off for IRSCP).

use crate::kernels::{IndexPattern, MicroOp, OpKind};
use crate::simulator::{simulate_microbench, MachineSpec, SimOptions};
use crate::util::report::{f, Table};

use super::ExpOptions;

/// Stride sweep: dense coverage at small k, powers of two with
/// neighbours at large k (to expose the trashing spikes).
pub fn stride_sweep(quick: bool) -> Vec<usize> {
    if quick {
        return vec![1, 2, 4, 8, 16, 31, 32, 33, 64, 128];
    }
    let mut v: Vec<usize> = (1..=32).collect();
    for k in [
        40, 48, 56, 63, 64, 65, 80, 96, 127, 128, 129, 160, 200, 255, 256, 257, 320, 400, 511,
        512, 513, 530, 640, 768, 1023, 1024,
    ] {
        v.push(k);
    }
    v
}

pub fn run(opts: &ExpOptions) -> Vec<Table> {
    let n = opts.micro_iters();
    let sim = SimOptions { warmup: false, ..Default::default() };
    let strides = stride_sweep(opts.quick);
    let mut tables = Vec::new();

    // --- Fig 3a: ISSCP and IRSCP vs stride, all machines ---
    for (label, make) in [
        (
            "ISSCP",
            Box::new(|k: usize| MicroOp { kind: OpKind::Scp, pattern: IndexPattern::IndexedStride(k) })
                as Box<dyn Fn(usize) -> MicroOp>,
        ),
        (
            "IRSCP",
            Box::new(|k: usize| MicroOp {
                kind: OpKind::Scp,
                pattern: IndexPattern::Geometric { mean: k as f64 },
            }),
        ),
    ] {
        let title = format!("Fig 3a — {label} cycles/update vs stride");
        let mut header: Vec<String> = vec!["stride".into()];
        header.extend(opts.machines.iter().map(|m| m.name.to_string()));
        let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&title, &href);
        for &k in &strides {
            let mut row = vec![k.to_string()];
            let b_len = (n * k * 2).max(4 << 20);
            for m in &opts.machines {
                let r = simulate_microbench(m, make(k), n, b_len, &sim, 42);
                row.push(f(r.cycles_per_update));
            }
            t.row(row);
        }
        tables.push(t);
    }

    // --- Fig 3b: prefetcher ablation on Woodcrest, IRSCP ---
    let wc = MachineSpec::woodcrest();
    let mut t = Table::new(
        "Fig 3b — IRSCP on Woodcrest: strided (SP) / adjacent-line (AP) prefetcher ablation, cycles/update",
        &["stride", "SP+AP", "SP only", "AP only", "none"],
    );
    let combos = [(true, true), (true, false), (false, true), (false, false)];
    for &k in &strides {
        let mut row = vec![k.to_string()];
        let b_len = (n * k * 2).max(4 << 20);
        let op = MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Geometric { mean: k as f64 } };
        for (sp, ap) in combos {
            let o = SimOptions { sp: Some(sp), ap: Some(ap), warmup: false };
            let r = simulate_microbench(&wc, op, n, b_len, &o, 42);
            row.push(f(r.cycles_per_update));
        }
        t.row(row);
    }
    tables.push(t);
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    fn irscp(k: f64) -> MicroOp {
        MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Geometric { mean: k } }
    }

    #[test]
    fn disabling_ap_helps_sparse_gathers() {
        // Fig 3b: AP off reduces memory traffic for isolated accesses.
        let wc = MachineSpec::woodcrest();
        let n = 30_000;
        let blen = 32 << 20;
        let on = SimOptions { sp: Some(false), ap: Some(true), warmup: false };
        let off = SimOptions { sp: Some(false), ap: Some(false), warmup: false };
        let with_ap = simulate_microbench(&wc, irscp(64.0), n, blen, &on, 1);
        let without = simulate_microbench(&wc, irscp(64.0), n, blen, &off, 1);
        assert!(
            without.dram_bytes < 0.7 * with_ap.dram_bytes,
            "AP off must cut traffic: {} vs {}",
            without.dram_bytes,
            with_ap.dram_bytes
        );
    }

    #[test]
    fn sp_is_crucial_for_dense_streams() {
        // Fig 3b: disabling SP for large regular strides is catastrophic
        // — and for stride-1 streams as well.
        let wc = MachineSpec::woodcrest();
        let n = 50_000;
        let on = SimOptions { sp: Some(true), ap: Some(false), warmup: false };
        let off = SimOptions { sp: Some(false), ap: Some(false), warmup: false };
        let op = MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Dense };
        let with_sp = simulate_microbench(&wc, op, n, 4 << 20, &on, 1);
        let without = simulate_microbench(&wc, op, n, 4 << 20, &off, 1);
        assert!(
            without.cycles_per_update > 1.5 * with_sp.cycles_per_update,
            "SP off {:.1} vs on {:.1}",
            without.cycles_per_update,
            with_sp.cycles_per_update
        );
    }

    #[test]
    fn power_of_two_spike_exists_on_woodcrest() {
        // ISSCP at k=512 (page-aligned power of two) must be no faster
        // than its odd neighbour k=530 class... the spike shows as 512
        // being slower than a nearby non-power-of-two of similar size.
        let wc = MachineSpec::woodcrest();
        let n = 30_000;
        let mk = |k: usize| MicroOp { kind: OpKind::Scp, pattern: IndexPattern::IndexedStride(k) };
        let blen = 64 << 20;
        let s512 = simulate_microbench(&wc, mk(512), n, blen, &SimOptions { warmup: false, ..Default::default() }, 1);
        let s400 = simulate_microbench(&wc, mk(400), n, blen, &SimOptions { warmup: false, ..Default::default() }, 1);
        assert!(
            s512.cycles_per_update >= s400.cycles_per_update * 0.95,
            "512 {:.1} vs 400 {:.1}",
            s512.cycles_per_update,
            s400.cycles_per_update
        );
    }

    #[test]
    fn driver_produces_tables() {
        let opts = ExpOptions { quick: true, ..Default::default() };
        let tables = run(&opts);
        assert_eq!(tables.len(), 3);
        assert!(tables[2].header.len() == 5);
    }
}
