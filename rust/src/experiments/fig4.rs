//! Fig 4 — IRSCP with Gaussian-distributed strides: mean and variance
//! controlled independently, allowing backward jumps at large variance.
//! Paper shapes: the ISSCP spike structure reappears at small variance;
//! stride jitter has minor effect; the geometric-distribution "bulge" is
//! absent; performance decreases smoothly with mean stride (Nehalem shows
//! no fine structure at all).

use crate::kernels::{IndexPattern, MicroOp, OpKind};
use crate::simulator::{simulate_microbench, SimOptions};
use crate::util::report::{f, Table};

use super::ExpOptions;

pub fn means(quick: bool) -> Vec<usize> {
    if quick {
        vec![1, 8, 64]
    } else {
        vec![1, 2, 4, 8, 16, 32, 64, 128, 256, 512]
    }
}

pub fn variances(quick: bool) -> Vec<f64> {
    if quick {
        vec![0.0, 64.0]
    } else {
        vec![0.0, 1.0, 4.0, 16.0, 64.0, 256.0, 1024.0, 4096.0, 16384.0]
    }
}

pub fn run(opts: &ExpOptions) -> Vec<Table> {
    let n = opts.micro_iters();
    let sim = SimOptions { warmup: false, ..Default::default() };
    let mut tables = Vec::new();
    for m in &opts.machines {
        // The paper shows Woodcrest (rich structure) and reports Nehalem
        // as smooth; we emit the grid for every requested machine.
        let title = format!(
            "Fig 4 — IRSCP Gaussian strides on {}: cycles/update (rows: mean, cols: variance)",
            m.name
        );
        let vars = variances(opts.quick);
        let mut header: Vec<String> = vec!["mean\\var".into()];
        header.extend(vars.iter().map(|v| format!("{v}")));
        let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&title, &href);
        for &mean in &means(opts.quick) {
            let mut row = vec![mean.to_string()];
            for &var in &vars {
                let op = MicroOp {
                    kind: OpKind::Scp,
                    pattern: IndexPattern::Gaussian { mean: mean as f64, variance: var },
                };
                let b_len = (n * mean * 2).max(8 << 20);
                let r = simulate_microbench(m, op, n, b_len, &sim, 42);
                row.push(f(r.cycles_per_update));
            }
            t.row(row);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::MachineSpec;

    fn gauss(mean: f64, var: f64) -> MicroOp {
        MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Gaussian { mean, variance: var } }
    }

    #[test]
    fn performance_decreases_with_mean_stride() {
        let m = MachineSpec::nehalem();
        let n = 30_000;
        let c1 = simulate_microbench(&m, gauss(1.0, 4.0), n, 8 << 20, &SimOptions { warmup: false, ..Default::default() }, 1);
        let c64 = simulate_microbench(&m, gauss(64.0, 4.0), n, 32 << 20, &SimOptions { warmup: false, ..Default::default() }, 1);
        assert!(
            c64.cycles_per_update > 2.0 * c1.cycles_per_update,
            "mean 64 {:.1} vs mean 1 {:.1}",
            c64.cycles_per_update,
            c1.cycles_per_update
        );
    }

    #[test]
    fn small_variance_jitter_has_minor_effect() {
        // Paper: "the stride jitter has only a minor effect".
        let m = MachineSpec::woodcrest();
        let n = 30_000;
        let a = simulate_microbench(&m, gauss(16.0, 0.0), n, 16 << 20, &SimOptions { warmup: false, ..Default::default() }, 1);
        let b = simulate_microbench(&m, gauss(16.0, 4.0), n, 16 << 20, &SimOptions { warmup: false, ..Default::default() }, 1);
        let rel = (a.cycles_per_update - b.cycles_per_update).abs() / a.cycles_per_update;
        assert!(rel < 0.35, "jitter effect {rel:.2} too large");
    }

    #[test]
    fn driver_emits_one_table_per_machine() {
        let opts = ExpOptions { quick: true, ..Default::default() };
        let tables = run(&opts);
        assert_eq!(tables.len(), 3);
        assert_eq!(tables[0].rows.len(), means(true).len());
    }
}
