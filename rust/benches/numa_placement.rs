//! NUMA placement trajectory bench: SpMV throughput with and without
//! thread pinning + first-touch workspace placement, plus the
//! `rebalance()` path that re-homes a plan after the schedule changes
//! (the paper's §5.2 dynamic-schedule migration hazard).
//!
//! Every configuration is self-validating: its output must stay
//! bit-identical to the serial CRS kernel before it is timed.
//!
//! Emits `results/BENCH_numa.json` (consumed by the CI regression gate
//! via `spmvperf benchdiff`). Scale: `SPMVPERF_BENCH_QUICK=1` for a
//! smoke pass.

use std::fmt::Write as _;

use spmvperf::engine::affinity;
use spmvperf::gen::{self, HolsteinHubbardParams};
use spmvperf::matrix::{Crs, Scheme, SpMv};
use spmvperf::sched::Schedule;
use spmvperf::spmv::{BackendChoice, SpmvHandle};
use spmvperf::tune::TuningPolicy;
use spmvperf::util::bench::{default_bench, quick_mode, write_bench_json};
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;
use spmvperf::util::stats::max_abs_diff;

const THREADS: usize = 4;

struct Config {
    name: &'static str,
    pinned: bool,
    schedule: Schedule,
    /// Build static first, then `rebalance()` onto `schedule` — the
    /// re-homing path rather than a fresh plan.
    via_rebalance: bool,
    threads: usize,
}

fn main() {
    let quick = quick_mode();
    let b = default_bench();
    let hh_params =
        if quick { HolsteinHubbardParams::tiny() } else { HolsteinHubbardParams::small() };
    let coo = gen::holstein_hubbard(&hh_params);
    let crs = Crs::from_coo(&coo);
    let n = crs.nrows;
    let nnz = crs.nnz() as u64;
    eprintln!(
        "matrix holstein-hubbard: N={n} nnz={nnz}, host CPUs {}, pinning {}",
        affinity::n_cpus(),
        if affinity::pin_supported() { "supported" } else { "unsupported (no-op fallback)" }
    );

    let mut rng = Rng::new(23);
    let mut x = vec![0.0; n];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let mut y_ref = vec![0.0; n];
    crs.spmv(&x, &mut y_ref);

    let static_sched = Schedule::Static { chunk: None };
    let dynamic_sched = Schedule::Dynamic { chunk: 64 };
    let mut configs = vec![
        Config {
            name: "unpinned-static",
            pinned: false,
            schedule: static_sched,
            via_rebalance: false,
            threads: THREADS,
        },
        Config {
            name: "pinned-static",
            pinned: true,
            schedule: static_sched,
            via_rebalance: false,
            threads: THREADS,
        },
        Config {
            name: "unpinned-dynamic",
            pinned: false,
            schedule: dynamic_sched,
            via_rebalance: false,
            threads: THREADS,
        },
        Config {
            name: "pinned-rebalanced",
            pinned: true,
            schedule: dynamic_sched,
            via_rebalance: true,
            threads: THREADS,
        },
    ];
    // Pinned scaling curve (fixed thread list so entry labels are stable
    // across hosts; oversubscribed threads just share cores).
    for &t in &[1usize, 2, 4] {
        configs.push(Config {
            name: match t {
                1 => "scaling-pinned-t1",
                2 => "scaling-pinned-t2",
                _ => "scaling-pinned-t4",
            },
            pinned: true,
            schedule: static_sched,
            via_rebalance: false,
            threads: t,
        });
    }

    let mut table = Table::new(
        "NUMA placement: SpMV throughput (CRS, Holstein-Hubbard)",
        &["config", "schedule", "threads", "placement", "MFlop/s", "ns/nnz"],
    );
    let mut entries: Vec<String> = Vec::new();
    let mut by_name: Vec<(&str, f64)> = Vec::new();
    for cfg in &configs {
        // Rebalance configs start from the static plan and re-home it
        // onto the target schedule; the rest build on it directly.
        let initial = if cfg.via_rebalance { static_sched } else { cfg.schedule };
        // Forced native: placement is an engine-layer property; the
        // auto-vs-forced executor dimension lives in backend_arbitration.
        let mut ctx = SpmvHandle::builder_from_crs(&crs)
            .policy(TuningPolicy::Fixed(Scheme::Crs, initial))
            .backend(BackendChoice::Native)
            .threads(cfg.threads)
            .pinned(cfg.pinned)
            .build()
            .expect("fixed native handle");
        if cfg.via_rebalance {
            ctx.rebalance(cfg.schedule);
        }
        // Self-validate before timing: placement must never change math.
        let mut y = vec![0.0; n];
        ctx.spmv(&x, &mut y);
        assert_eq!(
            max_abs_diff(&y_ref, &y),
            0.0,
            "{}: output deviates from serial CRS",
            cfg.name
        );
        // Time the serving path (`ctx.spmv`): the kernel traffic runs on
        // the plan's own workspace — the buffers first-touch placement
        // actually homed — with the gather/scatter overhead identical
        // across configurations. A caller-allocated permuted workspace
        // would bypass the placement under test.
        let r = b.run(&format!("numa/{}", cfg.name), nnz, 2 * nnz, || {
            ctx.spmv(&x, &mut y);
            y[0]
        });
        println!("{}", r.summary());
        let placement = ctx.report().placement.summary();
        table.row(vec![
            cfg.name.into(),
            ctx.schedule().name(),
            cfg.threads.to_string(),
            placement.clone(),
            f(r.mflops()),
            f(r.ns_per_item()),
        ]);
        by_name.push((cfg.name, r.mflops()));
        entries.push(format!(
            concat!(
                "    {{\"matrix\": \"holstein-hubbard\", \"config\": \"{}\", ",
                "\"schedule\": \"{}\", \"threads\": {}, \"pinned\": {}, ",
                "\"first_touch\": {}, \"placement\": \"{}\", ",
                "\"mflops\": {:.3}, \"ns_per_nnz\": {:.4}}}"
            ),
            cfg.name,
            ctx.schedule().name(),
            cfg.threads,
            cfg.pinned,
            ctx.plan().expect("native backend has a plan").first_touched(),
            placement,
            r.mflops(),
            r.ns_per_item(),
        ));
    }
    table.print();

    fn lookup(by_name: &[(&str, f64)], name: &str) -> f64 {
        by_name.iter().find(|(n, _)| *n == name).map(|(_, m)| *m).unwrap_or(0.0)
    }
    let pin_gain =
        lookup(&by_name, "pinned-static") / lookup(&by_name, "unpinned-static").max(1e-9);
    let rebalance_gain =
        lookup(&by_name, "pinned-rebalanced") / lookup(&by_name, "unpinned-dynamic").max(1e-9);
    println!(
        "pinned/unpinned static: {pin_gain:.3}x; rebalanced-pinned/unpinned dynamic: {rebalance_gain:.3}x"
    );

    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"numa_placement\",");
    let _ = writeln!(json, "  \"pin_supported\": {},", affinity::pin_supported());
    let _ = writeln!(json, "  \"host_cpus\": {},", affinity::n_cpus());
    let _ = writeln!(json, "  \"results\": [");
    let _ = writeln!(json, "{}", entries.join(",\n"));
    let _ = writeln!(json, "  ],");
    let _ = writeln!(json, "  \"summary\": [");
    let _ = writeln!(
        json,
        "    {{\"pinned_over_unpinned_static\": {pin_gain:.4}, \"rebalanced_over_unpinned_dynamic\": {rebalance_gain:.4}}}"
    );
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    write_bench_json("BENCH_numa.json", &json);
}
