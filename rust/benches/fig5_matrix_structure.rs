//! cargo-bench target regenerating the paper's fig5 (see DESIGN.md §3).
include!("common.rs");
fn main() { run_experiment_bench("fig5"); }
