// Shared helpers for the harness-free bench binaries. Each bench wraps
// one experiment driver, times it, and prints the regenerated tables —
// `cargo bench` therefore reproduces the paper's figures as text/CSV.
// Scale: default experiment sizes; set SPMVPERF_BENCH_QUICK=1 for a
// fast smoke pass or SPMVPERF_BENCH_FULL=1 for paper scale.

use spmvperf::experiments::ExpOptions;

pub fn bench_options() -> ExpOptions {
    let quick = std::env::var("SPMVPERF_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let full = std::env::var("SPMVPERF_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    ExpOptions {
        quick,
        full,
        csv_dir: Some("results".to_string()),
        ..Default::default()
    }
}

pub fn run_experiment_bench(id: &str) {
    let opts = bench_options();
    let t0 = std::time::Instant::now();
    spmvperf::experiments::run(id, &opts).expect("experiment failed");
    println!(
        "bench {id}: regenerated in {:.2}s (scale: {})",
        t0.elapsed().as_secs_f64(),
        if opts.full { "paper" } else if opts.quick { "quick" } else { "default" }
    );
}
