//! Sharded SpMV trajectory bench: shard counts × overlap modes — the
//! vector-mode vs task-mode comparison of arXiv:1106.5908 with shards
//! as in-process domains. Every configuration is self-validating (its
//! output must stay bit-identical to the serial CRS kernel before it is
//! timed) and records its halo-volume fraction, so the JSON documents
//! how much exchange each partition actually hides.
//!
//! Emits `results/BENCH_shard.json` (consumed by the CI regression gate
//! via `spmvperf benchdiff`). Scale: `SPMVPERF_BENCH_QUICK=1` for a
//! smoke pass.

use std::fmt::Write as _;

use spmvperf::gen::{self, HolsteinHubbardParams};
use spmvperf::kernels::Precision;
use spmvperf::matrix::{Crs, Scheme, SpMv};
use spmvperf::sched::Schedule;
use spmvperf::shard::OverlapMode;
use spmvperf::spmv::{BackendChoice, SpmvHandle};
use spmvperf::tune::{ShardPolicy, TuningPolicy};
use spmvperf::util::bench::{default_bench, quick_mode, write_bench_json};
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;
use spmvperf::util::stats::max_abs_diff;

const THREADS_PER_SHARD: usize = 1;
const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];
const SELL: Scheme = Scheme::SellCs { c: 8, sigma: 64 };

fn main() {
    let quick = quick_mode();
    let b = default_bench();
    let hh_params =
        if quick { HolsteinHubbardParams::tiny() } else { HolsteinHubbardParams::small() };
    let coo = gen::holstein_hubbard(&hh_params);
    let crs = Crs::from_coo(&coo);
    let n = crs.nrows;
    let nnz = crs.nnz() as u64;
    eprintln!("matrix holstein-hubbard: N={n} nnz={nnz}, {THREADS_PER_SHARD} thread(s)/shard");

    let mut rng = Rng::new(24);
    let mut x = vec![0.0; n];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let mut y_ref = vec![0.0; n];
    crs.spmv(&x, &mut y_ref);

    // (config name, shard count, scheme, precision): the CRS sweep over
    // the full shard grid plus one SELL-C-σ point, each in both overlap
    // modes — then the same s4 partitions under the Tolerance contract,
    // where the tuner arbitrates a vector ISA for the split kernels
    // (ISSUE 9) instead of forcing scalar.
    let mut configs: Vec<(String, usize, Scheme, Precision)> = SHARD_COUNTS
        .iter()
        .map(|&s| (format!("s{s}"), s, Scheme::Crs, Precision::BitIdentical))
        .collect();
    configs.push(("s4-sell".to_string(), 4, SELL, Precision::BitIdentical));
    configs.push(("s4-simd".to_string(), 4, Scheme::Crs, Precision::Tolerance(1e-12)));
    configs.push(("s4-sell-simd".to_string(), 4, SELL, Precision::Tolerance(1e-12)));

    let mut table = Table::new(
        "sharded SpMV: bulk-sync vs overlapped (Holstein-Hubbard)",
        &["config", "mode", "isa", "halo frac", "boundary nnz frac", "MFlop/s", "ns/nnz"],
    );
    let mut entries: Vec<String> = Vec::new();
    let mut by_name: Vec<(String, f64)> = Vec::new();
    let mut y = vec![0.0; n];
    for (name, shards, scheme, precision) in &configs {
        for mode in [OverlapMode::BulkSync, OverlapMode::Overlapped] {
            // Every configuration is a forced-sharded SpmvHandle — the
            // bench never names the executor type.
            let sh = SpmvHandle::builder_from_crs(&crs)
                .policy(TuningPolicy::Fixed(*scheme, Schedule::Static { chunk: None }))
                .backend(BackendChoice::Sharded)
                .shard_policy(ShardPolicy::Fixed { shards: *shards, mode })
                .threads(THREADS_PER_SHARD)
                .precision(*precision)
                .build()
                .expect("sharded handle over a square matrix");
            let label = format!("{name}-{}", short(mode));
            // Self-validate before timing: sharding and overlap must
            // never change the math — bit-identical under the default
            // contract, within ε when a vector ISA is bound.
            sh.spmv(&x, &mut y);
            match *precision {
                Precision::BitIdentical => assert_eq!(
                    max_abs_diff(&y_ref, &y),
                    0.0,
                    "{label}: output deviates from serial CRS"
                ),
                Precision::Tolerance(eps) => {
                    for i in 0..n {
                        assert!(
                            (y[i] - y_ref[i]).abs() <= eps * y_ref[i].abs().max(1.0),
                            "{label}: row {i} leaves the ε contract (isa {})",
                            sh.kernel_isa().name()
                        );
                    }
                }
            }
            let r = b.run(&format!("shard/{label}"), nnz, 2 * nnz, || {
                sh.spmv(&x, &mut y);
                y[0]
            });
            println!("{}", r.summary());
            let sd = sh.report().shard.as_ref().expect("shard decision recorded");
            let (halo_fraction, boundary_nnz_fraction) =
                (sd.halo_fraction, sd.boundary_nnz_fraction);
            table.row(vec![
                name.clone(),
                mode.name().into(),
                sh.kernel_isa().name().into(),
                f(halo_fraction),
                f(boundary_nnz_fraction),
                f(r.mflops()),
                f(r.ns_per_item()),
            ]);
            entries.push(format!(
                concat!(
                    "    {{\"matrix\": \"holstein-hubbard\", \"config\": \"{}\", ",
                    "\"shards\": {}, \"mode\": \"{}\", \"scheme\": \"{}\", ",
                    "\"precision\": \"{}\", \"isa\": \"{}\", ",
                    "\"threads_per_shard\": {}, \"halo_fraction\": {:.4}, ",
                    "\"boundary_nnz_fraction\": {:.4}, ",
                    "\"mflops\": {:.3}, \"ns_per_nnz\": {:.4}}}"
                ),
                label,
                shards,
                mode.name(),
                scheme.spec(),
                sh.precision().name(),
                sh.kernel_isa().name(),
                THREADS_PER_SHARD,
                halo_fraction,
                boundary_nnz_fraction,
                r.mflops(),
                r.ns_per_item(),
            ));
            by_name.push((label, r.mflops()));
        }
    }
    table.print();

    let lookup = |name: &str| {
        by_name.iter().find(|(n, _)| n == name).map(|(_, m)| *m).unwrap_or(0.0)
    };
    // The 1106.5908 comparison: overlapped/bulk-sync ratio per shard
    // count — the gain (or spawn-overhead loss) of hiding the exchange
    // behind the interior compute as the halo volume grows with cuts.
    let mut ratios = Vec::new();
    for &s in &SHARD_COUNTS {
        let bulk = lookup(&format!("s{s}-bulk"));
        let over = lookup(&format!("s{s}-overlap"));
        let ratio = over / bulk.max(1e-9);
        println!("s{s}: overlapped/bulk-sync = {ratio:.3}x");
        ratios.push((s, ratio));
    }

    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"shard_overlap\",");
    let _ = writeln!(json, "  \"results\": [");
    let _ = writeln!(json, "{}", entries.join(",\n"));
    let _ = writeln!(json, "  ],");
    let _ = writeln!(json, "  \"summary\": [");
    let summary: Vec<String> = ratios
        .iter()
        .map(|(s, r)| format!("    {{\"shards\": {s}, \"overlap_over_bulk\": {r:.4}}}"))
        .collect();
    let _ = writeln!(json, "{}", summary.join(",\n"));
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    write_bench_json("BENCH_shard.json", &json);
}

fn short(mode: OverlapMode) -> &'static str {
    match mode {
        OverlapMode::BulkSync => "bulk",
        OverlapMode::Overlapped => "overlap",
    }
}
