//! cargo-bench target regenerating the paper's fig4 (see DESIGN.md §3).
include!("common.rs");
fn main() { run_experiment_bench("fig4"); }
