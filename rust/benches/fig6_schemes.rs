//! Fig 6 (host edition): wall-clock SpMV throughput of every storage
//! scheme — the paper's six plus SELL-C-σ — through the plan/execute
//! engine at 1/2/4 threads on the Holstein-Hubbard test matrix.
//!
//! Emits the `BENCH_*.json` perf-trajectory format to
//! `results/BENCH_fig6_schemes.json` in addition to the text table.
//! Scale: `SPMVPERF_BENCH_QUICK=1` for a smoke pass.

use std::fmt::Write as _;

use spmvperf::gen::{self, HolsteinHubbardParams};
use spmvperf::matrix::{Crs, Scheme};
use spmvperf::sched::Schedule;
use spmvperf::spmv::{BackendChoice, SpmvHandle};
use spmvperf::tune::TuningPolicy;
use spmvperf::util::bench::{default_bench, quick_mode, write_bench_json};
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;

fn main() {
    let quick = quick_mode();
    let params = if quick { HolsteinHubbardParams::tiny() } else { HolsteinHubbardParams::small() };
    eprintln!("generating HH matrix (N = {}) ...", params.dimension());
    let h = gen::holstein_hubbard(&params);
    let crs = Crs::from_coo(&h);
    let mut rng = Rng::new(11);
    let mut x = vec![0.0; h.nrows];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let b = default_bench();
    let thread_counts: [usize; 3] = [1, 2, 4];

    let mut t = Table::new(
        "Fig 6 (host) — SpMV through tuned SpmvHandles (native backend)",
        &["scheme", "threads", "MFlop/s", "ns/nnz", "speedup vs serial CRS"],
    );
    let mut entries: Vec<String> = Vec::new();
    let mut serial_crs = 0.0f64;
    let mut crs4 = 0.0f64;
    for scheme in Scheme::all_extended(1000, 2, 32, 256) {
        let base = SpmvHandle::builder_from_crs(&crs)
            .policy(TuningPolicy::Fixed(scheme, Schedule::Static { chunk: None }))
            .backend(BackendChoice::Native)
            .threads(1)
            .build()
            .expect("fixed-policy native handle");
        let padding = base.report().padding_overhead;
        let mut ws = base.kernel().expect("native kernel").workspace(&x);
        for &nt in &thread_counts {
            let ctx = base
                .replanned(Schedule::Static { chunk: None }, nt)
                .expect("native handles replan");
            let nnz = ctx.kernel().expect("native kernel").nnz();
            let r = b.run(
                &format!("{} x{nt}", scheme.name()),
                nnz as u64,
                2 * nnz as u64,
                || {
                    ctx.spmv_permuted(&ws.xp, &mut ws.yp).expect("native permuted path");
                    ws.yp[0]
                },
            );
            println!("{}", r.summary());
            let mflops = r.mflops();
            if scheme == Scheme::Crs && nt == 1 {
                serial_crs = mflops;
            }
            if scheme == Scheme::Crs && nt == 4 {
                crs4 = mflops;
            }
            let speedup = if serial_crs > 0.0 { mflops / serial_crs } else { 0.0 };
            t.row(vec![
                scheme.name(),
                nt.to_string(),
                f(mflops),
                f(r.ns_per_item()),
                f(speedup),
            ]);
            entries.push(format!(
                concat!(
                    "    {{\"scheme\": \"{}\", \"spec\": \"{}\", \"threads\": {}, ",
                    "\"schedule\": \"static\", \"mflops\": {:.3}, \"ns_per_nnz\": {:.4}, ",
                    "\"speedup_vs_serial_crs\": {:.4}, \"padding_overhead\": {:.6}}}"
                ),
                scheme.name(),
                scheme.spec(),
                nt,
                mflops,
                r.ns_per_item(),
                speedup,
                padding,
            ));
        }
    }
    t.print();
    if serial_crs > 0.0 && crs4 > 0.0 {
        println!("engine speedup, CRS 4 threads vs serial CRS: {:.2}x", crs4 / serial_crs);
    }

    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"fig6_schemes\",");
    let _ = writeln!(
        json,
        "  \"matrix\": {{\"name\": \"holstein-hubbard\", \"n\": {}, \"nnz\": {}}},",
        h.nrows,
        h.nnz()
    );
    let _ = writeln!(json, "  \"results\": [");
    let _ = writeln!(json, "{}", entries.join(",\n"));
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    write_bench_json("BENCH_fig6_schemes.json", &json);
}
