//! Fig 6 (host edition): wall-clock SpMV throughput of every storage
//! scheme — the paper's six plus SELL-C-σ — through the plan/execute
//! engine at 1/2/4 threads on the Holstein-Hubbard test matrix.
//!
//! Emits the `BENCH_*.json` perf-trajectory format to
//! `results/BENCH_fig6_schemes.json` in addition to the text table.
//! Scale: `SPMVPERF_BENCH_QUICK=1` for a smoke pass.

use std::fmt::Write as _;

use spmvperf::gen::{self, HolsteinHubbardParams};
use spmvperf::kernels::Precision;
use spmvperf::matrix::{Crs, Scheme};
use spmvperf::sched::Schedule;
use spmvperf::spmv::{BackendChoice, SpmvHandle};
use spmvperf::tune::TuningPolicy;
use spmvperf::util::bench::{default_bench, quick_mode, write_bench_json};
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;

fn main() {
    let quick = quick_mode();
    let params = if quick { HolsteinHubbardParams::tiny() } else { HolsteinHubbardParams::small() };
    eprintln!("generating HH matrix (N = {}) ...", params.dimension());
    let h = gen::holstein_hubbard(&params);
    let crs = Crs::from_coo(&h);
    let mut rng = Rng::new(11);
    let mut x = vec![0.0; h.nrows];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let b = default_bench();
    let thread_counts: [usize; 3] = [1, 2, 4];

    let mut t = Table::new(
        "Fig 6 (host) — SpMV through tuned SpmvHandles (native backend)",
        &["scheme", "threads", "MFlop/s", "ns/nnz", "speedup vs serial CRS"],
    );
    let mut entries: Vec<String> = Vec::new();
    let mut serial_crs = 0.0f64;
    let mut crs4 = 0.0f64;
    for scheme in Scheme::all_extended(1000, 2, 32, 256) {
        let base = SpmvHandle::builder_from_crs(&crs)
            .policy(TuningPolicy::Fixed(scheme, Schedule::Static { chunk: None }))
            .backend(BackendChoice::Native)
            .threads(1)
            .build()
            .expect("fixed-policy native handle");
        let padding = base.report().padding_overhead;
        let mut ws = base.kernel().expect("native kernel").workspace(&x);
        for &nt in &thread_counts {
            let ctx = base
                .replanned(Schedule::Static { chunk: None }, nt)
                .expect("native handles replan");
            let nnz = ctx.kernel().expect("native kernel").nnz();
            let r = b.run(
                &format!("{} x{nt}", scheme.name()),
                nnz as u64,
                2 * nnz as u64,
                || {
                    ctx.spmv_permuted(&ws.xp, &mut ws.yp).expect("native permuted path");
                    ws.yp[0]
                },
            );
            println!("{}", r.summary());
            let mflops = r.mflops();
            if scheme == Scheme::Crs && nt == 1 {
                serial_crs = mflops;
            }
            if scheme == Scheme::Crs && nt == 4 {
                crs4 = mflops;
            }
            let speedup = if serial_crs > 0.0 { mflops / serial_crs } else { 0.0 };
            t.row(vec![
                scheme.name(),
                nt.to_string(),
                f(mflops),
                f(r.ns_per_item()),
                f(speedup),
            ]);
            // The "name" key is the benchdiff identity: scheme x threads
            // (plus the simd marker below) keys each row in the committed
            // results-baseline floors.
            entries.push(format!(
                concat!(
                    "    {{\"name\": \"{} x{}\", \"scheme\": \"{}\", \"spec\": \"{}\", ",
                    "\"threads\": {}, \"schedule\": \"static\", \"isa\": \"scalar\", ",
                    "\"mflops\": {:.3}, \"ns_per_nnz\": {:.4}, ",
                    "\"speedup_vs_serial_crs\": {:.4}, \"padding_overhead\": {:.6}}}"
                ),
                scheme.name(),
                nt,
                scheme.name(),
                scheme.spec(),
                nt,
                mflops,
                r.ns_per_item(),
                speedup,
                padding,
            ));
        }
    }

    // SIMD rows: the same fixed plans under the Tolerance contract. The
    // Fixed policy binds the detected ISA ceiling, so on AVX2+ hosts these
    // rows run the vector kernels against the scalar rows above (on a
    // scalar-only host they serve scalar and the row is a presence check).
    for scheme in [Scheme::Crs, Scheme::SellCs { c: 32, sigma: 256 }] {
        let ctx = SpmvHandle::builder_from_crs(&crs)
            .policy(TuningPolicy::Fixed(scheme, Schedule::Static { chunk: None }))
            .backend(BackendChoice::Native)
            .threads(4)
            .precision(Precision::Tolerance(1e-12))
            .build()
            .expect("fixed-policy simd handle");
        let padding = ctx.report().padding_overhead;
        let kernel = ctx.kernel().expect("native kernel");
        let mut ws = kernel.workspace(&x);
        let nnz = kernel.nnz();
        let r = b.run(
            &format!("{} x4 simd ({})", scheme.name(), ctx.kernel_isa().name()),
            nnz as u64,
            2 * nnz as u64,
            || {
                ctx.spmv_permuted(&ws.xp, &mut ws.yp).expect("native permuted path");
                ws.yp[0]
            },
        );
        println!("{}", r.summary());
        let mflops = r.mflops();
        let speedup = if serial_crs > 0.0 { mflops / serial_crs } else { 0.0 };
        t.row(vec![
            format!("{} simd", scheme.name()),
            "4".to_string(),
            f(mflops),
            f(r.ns_per_item()),
            f(speedup),
        ]);
        entries.push(format!(
            concat!(
                "    {{\"name\": \"{} x4 simd\", \"scheme\": \"{}\", \"spec\": \"{}\", ",
                "\"threads\": 4, \"schedule\": \"static\", \"precision\": \"tol:1e-12\", ",
                "\"isa\": \"{}\", \"mflops\": {:.3}, \"ns_per_nnz\": {:.4}, ",
                "\"speedup_vs_serial_crs\": {:.4}, \"padding_overhead\": {:.6}}}"
            ),
            scheme.name(),
            scheme.name(),
            scheme.spec(),
            ctx.kernel_isa().name(),
            mflops,
            r.ns_per_item(),
            speedup,
            padding,
        ));
    }
    t.print();
    if serial_crs > 0.0 && crs4 > 0.0 {
        println!("engine speedup, CRS 4 threads vs serial CRS: {:.2}x", crs4 / serial_crs);
    }

    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"fig6_schemes\",");
    let _ = writeln!(
        json,
        "  \"matrix\": {{\"name\": \"holstein-hubbard\", \"n\": {}, \"nnz\": {}}},",
        h.nrows,
        h.nnz()
    );
    let _ = writeln!(json, "  \"results\": [");
    let _ = writeln!(json, "{}", entries.join(",\n"));
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    write_bench_json("BENCH_fig6_schemes.json", &json);
}
