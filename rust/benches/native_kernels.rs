//! Host wall-clock benchmarks of the native SpMV kernels for every
//! storage scheme (and the Table-1 microbenchmark loops) — real
//! measurements on the host CPU, complementing the simulated figures.
//! SpMV runs through the plan/execute engine: serial and 4-thread
//! partitioned execution of the same plans.

use spmvperf::gen::{self, HolsteinHubbardParams};
use spmvperf::kernels::{table1_ops, MicroBuffers};
use spmvperf::matrix::{Crs, Scheme};
use spmvperf::sched::Schedule;
use spmvperf::spmv::{BackendChoice, SpmvHandle};
use spmvperf::tune::TuningPolicy;
use spmvperf::util::bench::{default_bench, quick_mode};
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;

fn main() {
    let quick = quick_mode();
    let params = if quick { HolsteinHubbardParams::tiny() } else { HolsteinHubbardParams::small() };
    eprintln!("generating HH matrix (N = {}) ...", params.dimension());
    let h = gen::holstein_hubbard(&params);
    let crs = Crs::from_coo(&h);
    let mut rng = Rng::new(9);
    let mut x = vec![0.0; h.nrows];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let b = default_bench();

    let mut t = Table::new(
        "native SpMV kernels via tuned contexts (host CPU)",
        &["scheme", "serial MFlop/s", "4T MFlop/s", "speedup", "ns/nnz (4T)"],
    );
    for scheme in Scheme::all_extended(1000, 2, 32, 256) {
        let ctx1 = SpmvHandle::builder_from_crs(&crs)
            .policy(TuningPolicy::Fixed(scheme, Schedule::Static { chunk: None }))
            .backend(BackendChoice::Native)
            .threads(1)
            .build()
            .expect("fixed-policy native handle");
        let ctx4 = ctx1
            .replanned(Schedule::Static { chunk: None }, 4)
            .expect("native handles replan");
        let kernel = ctx1.kernel().expect("native backend has a kernel");
        let mut ws = kernel.workspace(&x);
        let nnz = kernel.nnz() as u64;
        let r1 = b.run(&format!("{} serial", scheme.name()), nnz, 2 * nnz, || {
            ctx1.spmv_permuted(&ws.xp, &mut ws.yp).expect("native permuted path");
            ws.yp[0]
        });
        println!("{}", r1.summary());
        let r4 = b.run(&format!("{} x4", scheme.name()), nnz, 2 * nnz, || {
            ctx4.spmv_permuted(&ws.xp, &mut ws.yp).expect("native permuted path");
            ws.yp[0]
        });
        println!("{}", r4.summary());
        t.row(vec![
            scheme.name(),
            f(r1.mflops()),
            f(r4.mflops()),
            f(r4.mflops() / r1.mflops()),
            f(r4.ns_per_item()),
        ]);
    }
    t.print();

    let n = if quick { 20_000 } else { 500_000 };
    let blen = 8 << 20;
    let mut t2 = Table::new("Table-1 microbenchmark loops (host CPU, k=8)", &["op", "ns/update"]);
    for op in table1_ops(8) {
        let bufs = MicroBuffers::new(op, n, blen, 42);
        let r = b.run(&op.name(), n as u64, op.flops_per_iter() * n as u64, || bufs.run());
        println!("{}", r.summary());
        t2.row(vec![op.name(), f(r.ns_per_item())]);
    }
    t2.print();
}
