//! Host wall-clock benchmarks of the native SpMV kernels for every
//! storage scheme (and the Table-1 microbenchmark loops) — real
//! measurements on the host CPU, complementing the simulated figures.
//! SpMV runs through the plan/execute engine: serial and 4-thread
//! partitioned execution of the same plans.

use spmvperf::engine::{Engine, SpmvPlan};
use spmvperf::gen::{self, HolsteinHubbardParams};
use spmvperf::kernels::{table1_ops, MicroBuffers, SpmvKernel};
use spmvperf::matrix::Scheme;
use spmvperf::sched::Schedule;
use spmvperf::util::bench::default_bench;
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;

fn main() {
    let quick = std::env::var("SPMVPERF_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let params = if quick { HolsteinHubbardParams::tiny() } else { HolsteinHubbardParams::small() };
    eprintln!("generating HH matrix (N = {}) ...", params.dimension());
    let h = gen::holstein_hubbard(&params);
    let mut rng = Rng::new(9);
    let mut x = vec![0.0; h.nrows];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let b = default_bench();

    let engine1 = Engine::new(1);
    let engine4 = Engine::new(4);
    let mut t = Table::new(
        "native SpMV kernels via plan/execute (host CPU)",
        &["scheme", "serial MFlop/s", "4T MFlop/s", "speedup", "ns/nnz (4T)"],
    );
    for scheme in Scheme::all_extended(1000, 2, 32, 256) {
        let kernel = SpmvKernel::build(&h, scheme);
        let mut ws = kernel.workspace(&x);
        let nnz = kernel.nnz() as u64;
        let plan1 = SpmvPlan::new(&kernel, Schedule::Static { chunk: None }, 1);
        let r1 = b.run(&format!("{} serial", scheme.name()), nnz, 2 * nnz, || {
            plan1.execute_permuted(&engine1, &kernel, &ws.xp, &mut ws.yp);
            ws.yp[0]
        });
        println!("{}", r1.summary());
        let plan4 = SpmvPlan::new(&kernel, Schedule::Static { chunk: None }, 4);
        let r4 = b.run(&format!("{} x4", scheme.name()), nnz, 2 * nnz, || {
            plan4.execute_permuted(&engine4, &kernel, &ws.xp, &mut ws.yp);
            ws.yp[0]
        });
        println!("{}", r4.summary());
        t.row(vec![
            scheme.name(),
            f(r1.mflops()),
            f(r4.mflops()),
            f(r4.mflops() / r1.mflops()),
            f(r4.ns_per_item()),
        ]);
    }
    t.print();

    let n = if quick { 20_000 } else { 500_000 };
    let blen = 8 << 20;
    let mut t2 = Table::new("Table-1 microbenchmark loops (host CPU, k=8)", &["op", "ns/update"]);
    for op in table1_ops(8) {
        let bufs = MicroBuffers::new(op, n, blen, 42);
        let r = b.run(&op.name(), n as u64, op.flops_per_iter() * n as u64, || bufs.run());
        println!("{}", r.summary());
        t2.row(vec![op.name(), f(r.ns_per_item())]);
    }
    t2.print();
}
