//! Host wall-clock benchmarks of the native SpMV kernels for every
//! storage scheme (and the Table-1 microbenchmark loops) — real
//! measurements on the host CPU, complementing the simulated figures.
//! SpMV runs through the plan/execute engine: serial and 4-thread
//! partitioned execution of the same plans.

use spmvperf::gen::{self, HolsteinHubbardParams};
use spmvperf::kernels::microbench::{triad_isa, triad_scalar};
use spmvperf::kernels::{table1_ops, IsaLevel, MicroBuffers, Precision};
use spmvperf::matrix::{Crs, Scheme};
use spmvperf::sched::Schedule;
use spmvperf::spmv::{BackendChoice, SpmvHandle};
use spmvperf::tune::TuningPolicy;
use spmvperf::util::bench::{default_bench, quick_mode};
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;

fn main() {
    let quick = quick_mode();
    let params = if quick { HolsteinHubbardParams::tiny() } else { HolsteinHubbardParams::small() };
    eprintln!("generating HH matrix (N = {}) ...", params.dimension());
    let h = gen::holstein_hubbard(&params);
    let crs = Crs::from_coo(&h);
    let mut rng = Rng::new(9);
    let mut x = vec![0.0; h.nrows];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let b = default_bench();

    let mut t = Table::new(
        "native SpMV kernels via tuned contexts (host CPU)",
        &["scheme", "serial MFlop/s", "4T MFlop/s", "speedup", "ns/nnz (4T)"],
    );
    for scheme in Scheme::all_extended(1000, 2, 32, 256) {
        let ctx1 = SpmvHandle::builder_from_crs(&crs)
            .policy(TuningPolicy::Fixed(scheme, Schedule::Static { chunk: None }))
            .backend(BackendChoice::Native)
            .threads(1)
            .build()
            .expect("fixed-policy native handle");
        let ctx4 = ctx1
            .replanned(Schedule::Static { chunk: None }, 4)
            .expect("native handles replan");
        let kernel = ctx1.kernel().expect("native backend has a kernel");
        let mut ws = kernel.workspace(&x);
        let nnz = kernel.nnz() as u64;
        let r1 = b.run(&format!("{} serial", scheme.name()), nnz, 2 * nnz, || {
            ctx1.spmv_permuted(&ws.xp, &mut ws.yp).expect("native permuted path");
            ws.yp[0]
        });
        println!("{}", r1.summary());
        let r4 = b.run(&format!("{} x4", scheme.name()), nnz, 2 * nnz, || {
            ctx4.spmv_permuted(&ws.xp, &mut ws.yp).expect("native permuted path");
            ws.yp[0]
        });
        println!("{}", r4.summary());
        t.row(vec![
            scheme.name(),
            f(r1.mflops()),
            f(r4.mflops()),
            f(r4.mflops() / r1.mflops()),
            f(r4.ns_per_item()),
        ]);
    }
    t.print();

    // SIMD vs scalar under the Tolerance contract, on both test
    // matrices. The Fixed policy binds the detected ISA ceiling when the
    // scheme has a vector path, so the tol:1e-12 handle serves vectorized
    // kernels while the default BitIdentical handle stays scalar — same
    // plan shape, same schedule.
    let isa = IsaLevel::detect();
    let band_n = if quick { 2_000 } else { 60_000 };
    let mut band_rng = Rng::new(21);
    let band = Crs::from_coo(&gen::random_band(band_n, 12, band_n / 8, &mut band_rng));
    let mut xb = vec![0.0; band.nrows];
    rng.fill_f64(&mut xb, -1.0, 1.0);
    let mut ts = Table::new(
        &format!("simd vs scalar SpMV under tol:1e-12 (detected isa: {})", isa.name()),
        &[
            "matrix",
            "scheme",
            "scalar MFlop/s (4T)",
            "simd MFlop/s (4T)",
            "simd gain",
            "serving isa",
        ],
    );
    let cases: [(&str, &Crs, &Vec<f64>); 2] =
        [("holstein-hubbard", &crs, &x), ("random-band", &band, &xb)];
    for (mname, m, xv) in cases {
        for scheme in
            [Scheme::Crs, Scheme::SellCs { c: 8, sigma: 64 }, Scheme::SellCs { c: 32, sigma: 256 }]
        {
            let mut measured: Vec<(f64, IsaLevel)> = Vec::new();
            for precision in [Precision::BitIdentical, Precision::Tolerance(1e-12)] {
                let ctx = SpmvHandle::builder_from_crs(m)
                    .policy(TuningPolicy::Fixed(scheme, Schedule::Static { chunk: None }))
                    .backend(BackendChoice::Native)
                    .threads(4)
                    .precision(precision)
                    .build()
                    .expect("fixed-policy native handle");
                let kernel = ctx.kernel().expect("native backend has a kernel");
                let nnz = kernel.nnz() as u64;
                let mut ws = kernel.workspace(xv);
                let r = b.run(
                    &format!("{mname} {} x4 {}", scheme.name(), ctx.kernel_isa().name()),
                    nnz,
                    2 * nnz,
                    || {
                        ctx.spmv_permuted(&ws.xp, &mut ws.yp).expect("native permuted path");
                        ws.yp[0]
                    },
                );
                println!("{}", r.summary());
                measured.push((r.mflops(), ctx.kernel_isa()));
            }
            let (scalar_mf, _) = measured[0];
            let (simd_mf, simd_isa) = measured[1];
            ts.row(vec![
                mname.to_string(),
                scheme.name(),
                f(scalar_mf),
                f(simd_mf),
                f(simd_mf / scalar_mf),
                simd_isa.name().into(),
            ]);
        }
    }
    ts.print();

    let n = if quick { 20_000 } else { 500_000 };
    let blen = 8 << 20;
    let mut t2 = Table::new("Table-1 microbenchmark loops (host CPU, k=8)", &["op", "ns/update"]);
    for op in table1_ops(8) {
        let bufs = MicroBuffers::new(op, n, blen, 42);
        let r = b.run(&op.name(), n as u64, op.flops_per_iter() * n as u64, || bufs.run());
        println!("{}", r.summary());
        t2.row(vec![op.name(), f(r.ns_per_item())]);
    }
    t2.print();

    // Streaming triad, scalar vs vectorized — the same loop pair whose
    // measured gain feeds the heuristic tuner's simd-vs-scalar score.
    let tn = if quick { 16 * 1024 } else { 1 << 20 };
    let mut ta = vec![0.0; tn];
    let mut tb = vec![0.0; tn];
    let mut tc = vec![0.0; tn];
    let mut trng = Rng::new(7);
    trng.fill_f64(&mut tb, -1.0, 1.0);
    trng.fill_f64(&mut tc, -1.0, 1.0);
    let mut t3 = Table::new(
        "streaming triad a = b + s*c: scalar vs vectorized",
        &["variant", "MFlop/s", "ns/elem"],
    );
    let r = b.run("triad scalar", tn as u64, 2 * tn as u64, || {
        triad_scalar(&mut ta, &tb, &tc, 1.000001);
        ta[0]
    });
    println!("{}", r.summary());
    t3.row(vec!["scalar".into(), f(r.mflops()), f(r.ns_per_item())]);
    for visa in [IsaLevel::Avx2, IsaLevel::Avx512] {
        if visa > isa {
            continue;
        }
        let r = b.run(&format!("triad {}", visa.name()), tn as u64, 2 * tn as u64, || {
            triad_isa(visa, &mut ta, &tb, &tc, 1.000001);
            ta[0]
        });
        println!("{}", r.summary());
        t3.row(vec![visa.name().into(), f(r.mflops()), f(r.ns_per_item())]);
    }
    t3.print();
}
