//! Tuner trajectory bench: the auto-picked (scheme, C, σ, schedule)
//! versus the fixed `sellcs:32:256` default, plus the fused-batch
//! dispatch versus per-vector execution — evidence that the tuning
//! layer pays off matrix by matrix.
//!
//! Emits `results/BENCH_tune.json`. Scale: `SPMVPERF_BENCH_QUICK=1`
//! for a smoke pass.

use std::fmt::Write as _;

use spmvperf::gen::{self, HolsteinHubbardParams};
use spmvperf::kernels::Precision;
use spmvperf::matrix::{Coo, Crs, Scheme};
use spmvperf::sched::Schedule;
use spmvperf::spmv::{BackendChoice, SpmvHandle};
use spmvperf::tune::{sell_params, TuningPolicy};
use spmvperf::util::bench::{default_bench, quick_mode, write_bench_json};
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;

const BATCH: usize = 8;

fn main() {
    let quick = quick_mode();
    let b = default_bench();
    let threads = 4usize;

    let hh_params =
        if quick { HolsteinHubbardParams::tiny() } else { HolsteinHubbardParams::small() };
    let band_n = if quick { 2_000 } else { 60_000 };
    let mut band_rng = Rng::new(21);
    let matrices: Vec<(&str, Coo)> = vec![
        ("holstein-hubbard", gen::holstein_hubbard(&hh_params)),
        ("random-band", gen::random_band(band_n, 12, band_n / 8, &mut band_rng)),
    ];

    // The -simd variants rerun a policy under the Tolerance contract: the
    // tuner may then arbitrate vector kernels into the plan (Fixed binds
    // the detected ISA ceiling directly; the heuristic tier prices the
    // vector variants by the measured gather gain since ISSUE 9). The
    // default rows stay BitIdentical and therefore scalar.
    let policies: Vec<(&str, TuningPolicy, Precision)> = vec![
        (
            "fixed-sellcs-32-256",
            TuningPolicy::Fixed(
                Scheme::SellCs { c: 32, sigma: 256 },
                Schedule::Static { chunk: None },
            ),
            Precision::BitIdentical,
        ),
        ("heuristic", TuningPolicy::Heuristic, Precision::BitIdentical),
        ("measured", TuningPolicy::Measured, Precision::BitIdentical),
        (
            "fixed-sellcs-32-256-simd",
            TuningPolicy::Fixed(
                Scheme::SellCs { c: 32, sigma: 256 },
                Schedule::Static { chunk: None },
            ),
            Precision::Tolerance(1e-12),
        ),
        ("heuristic-simd", TuningPolicy::Heuristic, Precision::Tolerance(1e-12)),
        ("measured-simd", TuningPolicy::Measured, Precision::Tolerance(1e-12)),
    ];

    let mut entries: Vec<String> = Vec::new();
    let mut summaries: Vec<String> = Vec::new();
    for (mname, coo) in &matrices {
        eprintln!("matrix {mname}: N={} nnz={}", coo.nrows, coo.nnz());
        let crs = Crs::from_coo(coo);
        let n = crs.nrows;
        let mut rng = Rng::new(13);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let xs: Vec<Vec<f64>> = (0..BATCH)
            .map(|_| {
                let mut v = vec![0.0; n];
                rng.fill_f64(&mut v, -1.0, 1.0);
                v
            })
            .collect();

        let mut t = Table::new(
            &format!("tuning policies on {mname} ({threads} threads)"),
            &[
                "policy",
                "picked",
                "schedule",
                "isa",
                "MFlop/s",
                "ns/nnz",
                "padding",
                "batch amort.",
            ],
        );
        let mut fixed_mflops = 0.0f64;
        let mut heuristic_mflops = 0.0f64;
        for (pname, policy, precision) in &policies {
            // The native backend is forced: this bench isolates the
            // scheme/schedule tuning dimension (and the permuted hot
            // path exists only there); benches/backend_arbitration
            // covers the auto-vs-forced executor dimension.
            let ctx = SpmvHandle::builder_from_crs(&crs)
                .policy(*policy)
                .backend(BackendChoice::Native)
                .threads(threads)
                .quick(quick)
                .precision(*precision)
                .build()
                .expect("tuned native handle");
            let kernel = ctx.kernel().expect("native backend has a kernel");
            let nnz = kernel.nnz() as u64;
            let mut ws = kernel.workspace(&x);
            let r = b.run(&format!("{mname}/{pname}"), nnz, 2 * nnz, || {
                ctx.spmv_permuted(&ws.xp, &mut ws.yp).expect("native permuted path");
                ws.yp[0]
            });
            println!("{}", r.summary());
            // Fused single-dispatch batch vs the pre-fusion coordinator
            // loop (one spmv + one output clone per vector, as the
            // pre-PR-2 executor's run_batch did) — both return owned
            // batch results, so the metric compares the two service
            // paths.
            let r_fused = b.run(
                &format!("{mname}/{pname} batch{BATCH} fused"),
                BATCH as u64 * nnz,
                2 * BATCH as u64 * nnz,
                || {
                    let ys = ctx.spmv_batch(&xs);
                    ys[0][0]
                },
            );
            let r_pervec = b.run(
                &format!("{mname}/{pname} batch{BATCH} per-vec"),
                BATCH as u64 * nnz,
                2 * BATCH as u64 * nnz,
                || {
                    let mut ys = Vec::with_capacity(xs.len());
                    let mut y = vec![0.0; n];
                    for xv in &xs {
                        ctx.spmv(xv, &mut y);
                        ys.push(y.clone());
                    }
                    ys[0][0]
                },
            );
            // Blocked-x SpMM (ISSUE 9): the fused multi kernel streams
            // the matrix once per column block — with vector bodies, a
            // bound ISA keeps its win instead of falling back to the
            // per-vector batch.
            let r_multi = b.run(
                &format!("{mname}/{pname} batch{BATCH} blocked-x"),
                BATCH as u64 * nnz,
                2 * BATCH as u64 * nnz,
                || {
                    let ys = ctx.spmv_multi(&xs);
                    ys[0][0]
                },
            );
            let amortization = r_pervec.median_secs() / r_fused.median_secs();
            let mflops = r.mflops();
            if *pname == "fixed-sellcs-32-256" {
                fixed_mflops = mflops;
            }
            if *pname == "heuristic" {
                heuristic_mflops = mflops;
            }
            let (c, sigma) = sell_params(ctx.scheme());
            t.row(vec![
                pname.to_string(),
                ctx.scheme().name(),
                ctx.schedule().name(),
                ctx.kernel_isa().name().into(),
                f(mflops),
                f(r.ns_per_item()),
                f(ctx.report().padding_overhead),
                f(amortization),
            ]);
            entries.push(format!(
                concat!(
                    "    {{\"matrix\": \"{}\", \"n\": {}, \"nnz\": {}, \"policy\": \"{}\", ",
                    "\"precision\": \"{}\", \"isa\": \"{}\", ",
                    "\"scheme\": \"{}\", \"spec\": \"{}\", \"c\": {}, \"sigma\": {}, ",
                    "\"schedule\": \"{}\", \"threads\": {}, \"mflops\": {:.3}, ",
                    "\"ns_per_nnz\": {:.4}, \"padding_overhead\": {:.6}, ",
                    "\"batch{}_fused_mflops\": {:.3}, \"batch_amortization\": {:.4}, ",
                    "\"batch{}_multi_mflops\": {:.3}, \"multi_blocked\": {}}}"
                ),
                mname,
                n,
                kernel.nnz(),
                pname,
                ctx.precision().name(),
                ctx.kernel_isa().name(),
                ctx.scheme().name(),
                ctx.scheme().spec(),
                c,
                sigma,
                ctx.schedule().name(),
                threads,
                mflops,
                r.ns_per_item(),
                ctx.report().padding_overhead,
                BATCH,
                r_fused.mflops(),
                amortization,
                BATCH,
                r_multi.mflops(),
                ctx.multi_decision(BATCH).blocked,
            ));
        }
        t.print();
        let paying_off = heuristic_mflops >= fixed_mflops;
        println!(
            "{mname}: heuristic {heuristic_mflops:.1} vs fixed sellcs:32:256 {fixed_mflops:.1} MFlop/s -> tuner {}",
            if paying_off { "pays off" } else { "trails the default here" }
        );
        summaries.push(format!(
            concat!(
                "    {{\"matrix\": \"{}\", \"fixed_mflops\": {:.3}, ",
                "\"heuristic_mflops\": {:.3}, \"heuristic_ge_fixed\": {}}}"
            ),
            mname, fixed_mflops, heuristic_mflops, paying_off
        ));
    }

    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"tune_policies\",");
    let _ = writeln!(json, "  \"threads\": {threads},");
    let _ = writeln!(json, "  \"results\": [");
    let _ = writeln!(json, "{}", entries.join(",\n"));
    let _ = writeln!(json, "  ],");
    let _ = writeln!(json, "  \"summary\": [");
    let _ = writeln!(json, "{}", summaries.join(",\n"));
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    write_bench_json("BENCH_tune.json", &json);
}
