//! Backend-arbitration trajectory bench: the auto-picked executor
//! (serial / native / sharded, chosen per matrix by the `SpmvBuilder`
//! arbitration tier) versus each forced backend — evidence that the
//! executor decision, like the scheme decision, is a property of the
//! matrix and pays off (or at least never collapses) matrix by matrix.
//!
//! Every configuration is self-validating (1e-12 against the serial CRS
//! reference — the auto pick may choose any scheme) and records which
//! backend actually served it.
//!
//! Emits `results/BENCH_arbitration.json` (consumed by the CI
//! regression gate via `spmvperf benchdiff`). Scale:
//! `SPMVPERF_BENCH_QUICK=1` for a smoke pass.

use std::fmt::Write as _;

use spmvperf::gen::{self, HolsteinHubbardParams};
use spmvperf::matrix::{Coo, Crs, SpMv};
use spmvperf::spmv::{BackendChoice, SpmvHandle};
use spmvperf::tune::TuningPolicy;
use spmvperf::util::bench::{default_bench, quick_mode, write_bench_json};
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;

const THREADS: usize = 4;

fn main() {
    let quick = quick_mode();
    let b = default_bench();
    let hh_params =
        if quick { HolsteinHubbardParams::tiny() } else { HolsteinHubbardParams::small() };
    let band_n = if quick { 2_000 } else { 60_000 };
    let mut band_rng = Rng::new(31);
    let matrices: Vec<(&str, Coo)> = vec![
        ("holstein-hubbard", gen::holstein_hubbard(&hh_params)),
        ("random-band", gen::random_band(band_n, 12, band_n / 8, &mut band_rng)),
    ];

    let configs: [(&str, BackendChoice); 3] = [
        ("auto", BackendChoice::Auto),
        ("forced-native", BackendChoice::Native),
        ("forced-sharded", BackendChoice::Sharded),
    ];

    let mut entries: Vec<String> = Vec::new();
    let mut summaries: Vec<String> = Vec::new();
    for (mname, coo) in &matrices {
        let crs = Crs::from_coo(coo);
        let n = crs.nrows;
        let nnz = crs.nnz() as u64;
        eprintln!("matrix {mname}: N={n} nnz={nnz}");
        let mut rng = Rng::new(32);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y_ref = vec![0.0; n];
        crs.spmv(&x, &mut y_ref);

        let mut t = Table::new(
            &format!("backend arbitration on {mname} ({THREADS} threads)"),
            &["config", "served by", "scheme", "shards", "MFlop/s", "ns/nnz"],
        );
        let mut by_config: Vec<(&str, f64)> = Vec::new();
        let mut y = vec![0.0; n];
        for (cname, choice) in configs {
            let handle = SpmvHandle::builder_from_crs(&crs)
                .policy(TuningPolicy::Heuristic)
                .backend(choice)
                .threads(THREADS)
                .quick(quick)
                .build()
                .expect("tuned handle");
            let decision = handle.backend_decision().expect("decision recorded");
            assert_eq!(decision.candidates.iter().filter(|c| c.chosen).count(), 1);
            // Self-validate before timing: arbitration must never change
            // the math (1e-12: the auto pick may choose any scheme).
            handle.spmv(&x, &mut y);
            let err = spmvperf::util::stats::max_abs_diff(&y_ref, &y);
            assert!(err < 1e-12, "{mname}/{cname}: deviates from serial CRS by {err:.2e}");
            let r = b.run(&format!("{mname}/{cname}"), nnz, 2 * nnz, || {
                handle.spmv(&x, &mut y);
                y[0]
            });
            println!("{}", r.summary());
            t.row(vec![
                cname.to_string(),
                handle.backend_name().into(),
                handle.scheme().name(),
                handle.n_shards().to_string(),
                f(r.mflops()),
                f(r.ns_per_item()),
            ]);
            by_config.push((cname, r.mflops()));
            entries.push(format!(
                concat!(
                    "    {{\"matrix\": \"{}\", \"config\": \"{}\", \"backend\": \"{}\", ",
                    "\"arbitration\": \"{}\", \"scheme\": \"{}\", \"shards\": {}, ",
                    "\"threads\": {}, \"mflops\": {:.3}, \"ns_per_nnz\": {:.4}}}"
                ),
                mname,
                cname,
                handle.backend_name(),
                decision.policy,
                handle.scheme().spec(),
                handle.n_shards(),
                THREADS,
                r.mflops(),
                r.ns_per_item(),
            ));
        }
        t.print();
        let lookup = |name: &str| {
            by_config.iter().find(|(c, _)| *c == name).map(|(_, m)| *m).unwrap_or(0.0)
        };
        let auto = lookup("auto");
        let best_forced = lookup("forced-native").max(lookup("forced-sharded"));
        let ratio = auto / best_forced.max(1e-9);
        println!(
            "{mname}: auto {auto:.1} vs best forced {best_forced:.1} MFlop/s \
             ({ratio:.3}x of best forced)"
        );
        summaries.push(format!(
            concat!(
                "    {{\"matrix\": \"{}\", \"auto_mflops\": {:.3}, ",
                "\"best_forced_mflops\": {:.3}, \"auto_over_best_forced\": {:.4}}}"
            ),
            mname, auto, best_forced, ratio
        ));
    }

    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"backend_arbitration\",");
    let _ = writeln!(json, "  \"threads\": {THREADS},");
    let _ = writeln!(json, "  \"results\": [");
    let _ = writeln!(json, "{}", entries.join(",\n"));
    let _ = writeln!(json, "  ],");
    let _ = writeln!(json, "  \"summary\": [");
    let _ = writeln!(json, "{}", summaries.join(",\n"));
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    write_bench_json("BENCH_arbitration.json", &json);
}
