//! PJRT dispatch overhead and batched-SpMV throughput through the AOT
//! JAX/Pallas artifacts: how much the coordinator's dynamic batching
//! amortizes per-call costs. Requires `make artifacts`.

use spmvperf::gen::{self, HolsteinHubbardParams};
use spmvperf::matrix::{Crs, EllMatrix, SpMv};
use spmvperf::runtime::{default_artifacts_dir, Runtime};
use spmvperf::util::bench::default_bench;
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;

fn main() {
    if !default_artifacts_dir().join("spmv_d24_n540.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts` first");
        return;
    }
    let h = gen::holstein_hubbard(&HolsteinHubbardParams::tiny());
    let crs = Crs::from_coo(&h);
    let ell = EllMatrix::from_crs(&crs, Some(24)).unwrap();
    let rt = Runtime::new(&default_artifacts_dir()).unwrap();
    let single = rt.bind(&ell, rt.load("spmv_d24_n540.hlo.txt").unwrap()).unwrap();
    let batched = rt.bind(&ell, rt.load("spmv_b8_d24_n540.hlo.txt").unwrap()).unwrap();

    let mut rng = Rng::new(3);
    let mut x = vec![0.0; ell.n];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let xs: Vec<Vec<f64>> = (0..8).map(|_| x.clone()).collect();
    let b = default_bench();
    let flops = 2 * crs.nnz() as u64;

    let r1 = b.run("pjrt spmv (batch 1)", crs.nnz() as u64, flops, || {
        single.spmv(&x).unwrap()[0]
    });
    let r8 = b.run("pjrt spmv (batch 8)", 8 * crs.nnz() as u64, 8 * flops, || {
        batched.spmv_batched(&xs).unwrap()[0][0]
    });
    // native baseline
    let mut y = vec![0.0; ell.n];
    let rn = b.run("native ELL spmv", crs.nnz() as u64, flops, || {
        ell.spmv_permuted(&x, &mut y);
        y[0]
    });

    println!("{}", r1.summary());
    println!("{}", r8.summary());
    println!("{}", rn.summary());
    let mut t = Table::new("PJRT dispatch & batching", &["path", "us/request", "MFlop/s"]);
    t.row(vec!["pjrt batch=1".into(), f(r1.median_secs() * 1e6), f(r1.mflops())]);
    t.row(vec![
        "pjrt batch=8".into(),
        f(r8.median_secs() * 1e6 / 8.0),
        f(r8.mflops()),
    ]);
    t.row(vec!["native".into(), f(rn.median_secs() * 1e6), f(rn.mflops())]);
    t.print();
    println!(
        "batching amortization: {:.2}x lower per-request cost at batch 8",
        r1.median_secs() / (r8.median_secs() / 8.0)
    );
}
