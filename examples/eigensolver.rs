//! END-TO-END driver: the paper's motivating application (§1 — sparse
//! eigensolvers dominated by SpMVM) through the FULL three-layer stack:
//!
//!   Rust coordinator -> PJRT runtime -> AOT HLO artifact containing the
//!   JAX/Pallas ELL SpMV kernel (python never runs here).
//!
//! Finds the Holstein-Hubbard ground state with Lanczos where every
//! SpMV executes the compiled Pallas kernel, then validates against
//! (a) the native Rust CRS Lanczos and (b) a dense Jacobi reference on
//! a smaller system, and reports SpMV throughput for both paths.
//!
//! Requires `make artifacts` first:
//!     cargo run --release --example eigensolver

use std::time::Instant;

use spmvperf::eigen::{jacobi_eigen, lanczos, LanczosConfig};
use spmvperf::gen::{holstein_hubbard, HolsteinHubbardParams};
use spmvperf::matrix::{Crs, EllMatrix, SpMv};
use spmvperf::runtime::{default_artifacts_dir, PjrtOp, Runtime};
use spmvperf::util::report::{f, Table};

fn main() -> anyhow::Result<()> {
    // --- the physical system (paper Fig 5, tiny truncation to match the
    //     static artifact shape d=24, n=540) ---
    let params = HolsteinHubbardParams::tiny();
    eprintln!(
        "Holstein-Hubbard chain: L={} sites, {}+{} electrons, <= {} phonons, dim {}",
        params.sites, params.n_up, params.n_down, params.max_phonons, params.dimension()
    );
    let h = holstein_hubbard(&params);
    let crs = Crs::from_coo(&h);
    let ell = EllMatrix::from_crs(&crs, Some(24))?;

    // --- full-stack path: PJRT-compiled Pallas kernel ---
    let rt = Runtime::new(&default_artifacts_dir())?;
    eprintln!("PJRT platform: {}; artifacts: {:?}", rt.platform(), rt.available());
    let bound = rt.bind(&ell, rt.load("spmv_d24_n540.hlo.txt")?)?;
    let op = PjrtOp { bound: &bound, ell: &ell };

    let cfg = LanczosConfig::default();
    let t0 = Instant::now();
    let via_pjrt = lanczos(&op, 1, &cfg);
    let t_pjrt = t0.elapsed();

    // --- native path for comparison ---
    let t0 = Instant::now();
    let via_native = lanczos(&crs, 1, &cfg);
    let t_native = t0.elapsed();

    // --- dense cross-validation on a smaller system ---
    let small = HolsteinHubbardParams {
        sites: 3, n_up: 1, n_down: 1, max_phonons: 2, ..params
    };
    let hs = holstein_hubbard(&small);
    let (dense_evals, _) = jacobi_eigen(&hs.to_dense(), false);
    let lz_small = lanczos(&Crs::from_coo(&hs), 1, &cfg);

    let flops = |r: &spmvperf::eigen::LanczosResult, dt: std::time::Duration| {
        2.0 * crs.nnz() as f64 * r.spmv_count as f64 / dt.as_secs_f64() / 1e6
    };
    let mut t = Table::new("Lanczos ground state, full stack vs native", &["metric", "PJRT/Pallas", "native CRS"]);
    t.row(vec![
        "E0".into(),
        format!("{:.10}", via_pjrt.eigenvalues[0]),
        format!("{:.10}", via_native.eigenvalues[0]),
    ]);
    t.row(vec![
        "iterations".into(),
        via_pjrt.iterations.to_string(),
        via_native.iterations.to_string(),
    ]);
    t.row(vec![
        "converged".into(),
        via_pjrt.converged.to_string(),
        via_native.converged.to_string(),
    ]);
    t.row(vec!["wall time (s)".into(), f(t_pjrt.as_secs_f64()), f(t_native.as_secs_f64())]);
    t.row(vec![
        "SpMV MFlop/s".into(),
        f(flops(&via_pjrt, t_pjrt)),
        f(flops(&via_native, t_native)),
    ]);
    t.print();

    let diff = (via_pjrt.eigenvalues[0] - via_native.eigenvalues[0]).abs();
    println!("PJRT vs native E0 difference: {diff:.2e}");
    assert!(diff < 1e-8, "full-stack result must match the native solver");
    let dd = (dense_evals[0] - lz_small.eigenvalues[0]).abs();
    println!(
        "dense Jacobi cross-check (dim {}): E0 = {:.10}, Lanczos = {:.10} (diff {dd:.2e})",
        hs.nrows, dense_evals[0], lz_small.eigenvalues[0]
    );
    assert!(dd < 1e-8);
    println!("END-TO-END OK: all three layers agree on the ground-state energy.");
    Ok(())
}
