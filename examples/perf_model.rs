//! The paper's §1 goal: predict SpMV performance per storage scheme from
//! the matrix's stride-distribution fingerprint alone, then check the
//! prediction against the full memory-hierarchy simulation.
//!
//!     cargo run --release --example perf_model

use spmvperf::analysis::StrideDistribution;
use spmvperf::gen::{holstein_hubbard, HolsteinHubbardParams};
use spmvperf::kernels::SpmvKernel;
use spmvperf::matrix::{Crs, Scheme};
use spmvperf::perfmodel::{predict, CostCurve};
use spmvperf::sched::Schedule;
use spmvperf::simulator::{simulate_spmv, MachineSpec, Placement, SimOptions};
use spmvperf::util::report::{f, Table};

fn main() {
    let machine = MachineSpec::woodcrest();
    eprintln!("generating test matrix (N = 369,600) ...");
    let h = holstein_hubbard(&HolsteinHubbardParams::medium());
    let crs = Crs::from_coo(&h);

    eprintln!("calibrating {} gather-cost curve (Fig 3a analogue) ...", machine.name);
    let curve = CostCurve::calibrate(&machine, 40_000);
    let mut ct = Table::new("calibrated IRSCP cost curve", &["mean stride", "cycles/update"]);
    for (k, c) in &curve.points {
        ct.row(vec![f(*k), f(*c)]);
    }
    ct.print();

    let mut t = Table::new(
        &format!("fingerprint prediction vs full simulation on {}", machine.name),
        &["scheme", "backward frac", "mean |stride|", "pred cyc/nnz", "sim cyc/nnz", "pred/sim"],
    );
    for scheme in Scheme::all_with(1000, 2) {
        eprint!("  {} ...\r", scheme.name());
        let k = SpmvKernel::build_from_crs(&crs, scheme);
        let dist = StrideDistribution::from_kernel(&k);
        let pred = predict(&machine, &curve, &k);
        let sim = simulate_spmv(
            &machine,
            &k,
            1,
            1,
            Schedule::Static { chunk: None },
            Placement::FirstTouchStatic,
            &SimOptions::default(),
        );
        let sim_cyc = sim.cycles / sim.updates as f64;
        t.row(vec![
            scheme.name(),
            f(dist.backward_fraction()),
            f(dist.mean_abs_stride()),
            f(pred.cycles_per_nnz),
            f(sim_cyc),
            f(pred.cycles_per_nnz / sim_cyc),
        ]);
    }
    t.print();
    println!("The model ranks schemes from the sparsity fingerprint alone (paper §1).");
}
