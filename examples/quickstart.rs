//! Quickstart: generate the paper's Holstein-Hubbard test matrix (tiny
//! truncation), realize it in every storage scheme from §2, run SpMV
//! through each, verify they agree, and print host wall-clock rates.
//!
//!     cargo run --release --example quickstart

use spmvperf::gen::{holstein_hubbard, HolsteinHubbardParams};
use spmvperf::kernels::SpmvKernel;
use spmvperf::matrix::Scheme;
use spmvperf::util::bench::Bench;
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;
use spmvperf::util::stats::max_abs_diff;

fn main() {
    // 1. The paper's test matrix (Fig 5), scaled down: L=6 sites,
    //    3+3 electrons, up to 4 phonons -> N = 84,000.
    let params = HolsteinHubbardParams::small();
    eprintln!("generating Holstein-Hubbard Hamiltonian, N = {} ...", params.dimension());
    let h = holstein_hubbard(&params);
    eprintln!("nnz = {} ({:.1} per row)", h.nnz(), h.nnz() as f64 / h.nrows as f64);

    // 2. Build every storage scheme and check they all agree with CRS.
    let mut rng = Rng::new(42);
    let mut x = vec![0.0; h.nrows];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let crs = SpmvKernel::build(&h, Scheme::Crs);
    let mut y_ref = vec![0.0; h.nrows];
    crs.spmv(&x, &mut y_ref);

    let mut table = Table::new(
        "SpMV on the Holstein-Hubbard matrix — all storage schemes (host CPU)",
        &["scheme", "max |err| vs CRS", "host MFlop/s", "ns per nnz"],
    );
    for scheme in Scheme::all_extended(1000, 2, 32, 256) {
        let kernel = SpmvKernel::build(&h, scheme);
        let mut y = vec![0.0; h.nrows];
        kernel.spmv(&x, &mut y);
        let err = max_abs_diff(&y_ref, &y);
        assert!(err < 1e-10, "{scheme} disagrees with CRS");
        // hot-loop timing in the permuted basis (as a solver would run)
        let mut ws = kernel.workspace(&x);
        let r = Bench::quick().run(&scheme.name(), kernel.nnz() as u64, 2 * kernel.nnz() as u64, || {
            kernel.spmv_hot(&mut ws);
            ws.yp[0]
        });
        table.row(vec![scheme.name(), format!("{err:.2e}"), f(r.mflops()), f(r.ns_per_item())]);
    }
    table.print();
    println!("All schemes agree. See `spmvperf experiment fig6` for the paper's comparison.");
}
