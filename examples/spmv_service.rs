//! SpMV-as-a-service demo: a short client of [`spmvperf::serve::Server`]
//! — the paper's SpMVM as the hot path of a serving system. Two tenants
//! register the same Holstein-Hubbard Hamiltonian (the second hits the
//! tuned-handle cache), an open-loop burst of requests is coalesced into
//! batched dispatches, and the printed stats show the amortization.
//!
//!     cargo run --release --example spmv_service [requests] [window_us]

use std::time::{Duration, Instant};

use spmvperf::gen::{holstein_hubbard, HolsteinHubbardParams};
use spmvperf::matrix::{Crs, SpMv};
use spmvperf::serve::{ServeConfig, Server};
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let window_us: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(300);

    let crs = Crs::from_coo(&holstein_hubbard(&HolsteinHubbardParams::tiny()));
    let n = crs.nrows;

    let mut server = Server::start(ServeConfig {
        max_delay: Duration::from_micros(window_us),
        ..ServeConfig::default()
    });
    // Same matrix, two tenants: the first registration tunes a handle,
    // the second reuses it from the fingerprint-keyed cache.
    for tenant in ["alice", "bob"] {
        let outcome = server.register(tenant, crs.clone())?;
        eprintln!("register {tenant}: cache {}", outcome.name());
    }

    // Open-loop burst: fire everything, then gather. Tickets block until
    // the dispatcher serves their coalesced batch.
    let mut rng = Rng::new(1234);
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..requests)
        .map(|i| {
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            let tenant = if i % 2 == 0 { "alice" } else { "bob" };
            (x.clone(), server.submit(tenant, x).expect("admitted"))
        })
        .collect();
    let mut max_err = 0.0f64;
    for (x, ticket) in tickets {
        let y = ticket.wait();
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        for (a, b) in y.iter().zip(&want) {
            max_err = max_err.max((a - b).abs());
        }
    }
    let dt = t0.elapsed();
    anyhow::ensure!(max_err < 1e-12, "served results diverged: {max_err:e}");

    let stats = server.stats();
    let mut t = Table::new("serve stats", &["metric", "value"]);
    t.row(vec!["requests".into(), stats.completed.to_string()]);
    t.row(vec!["dispatches".into(), stats.dispatches.to_string()]);
    t.row(vec!["avg batch size".into(), f(stats.avg_batch())]);
    let cache = format!("{} / {}", stats.cache_hits, stats.cache_misses);
    t.row(vec!["cache hits / misses".into(), cache]);
    t.row(vec!["shed".into(), stats.shed.to_string()]);
    t.row(vec!["throughput (req/s)".into(), f(requests as f64 / dt.as_secs_f64())]);
    t.row(vec!["max |err| vs serial".into(), format!("{max_err:.1e}")]);
    t.print();
    server.shutdown();
    Ok(())
}
