//! SpMV-as-a-service demo: the L3 coordinator routing batched requests
//! to the PJRT-compiled JAX/Pallas kernel, with a synthetic open-loop
//! load generator and latency/throughput/batching metrics — the paper's
//! SpMVM as the hot path of a serving system.
//!
//! Falls back to the native executor when artifacts are missing.
//!
//!     cargo run --release --example spmv_service [requests] [window_us]

use std::sync::atomic::Ordering::Relaxed;
use std::time::{Duration, Instant};

use spmvperf::coordinator::{
    BatchExecutor, Coordinator, Executor, PjrtExecutor, Service, ServiceConfig,
};
use spmvperf::gen::{holstein_hubbard, HolsteinHubbardParams};
use spmvperf::matrix::{Crs, EllMatrix};
use spmvperf::runtime::{default_artifacts_dir, Runtime};
use spmvperf::spmv::SpmvHandle;
use spmvperf::tune::TuningPolicy;
use spmvperf::util::report::{f, Table};
use spmvperf::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let window_us: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(300);

    let h = holstein_hubbard(&HolsteinHubbardParams::tiny());
    let ell = EllMatrix::from_crs(&Crs::from_coo(&h), Some(24))?;
    let n = ell.n;

    let have_artifacts = default_artifacts_dir().join("spmv_b8_d24_n540.hlo.txt").exists();
    let backend = if have_artifacts { "pjrt/pallas" } else { "native (artifacts missing)" };
    eprintln!("starting service: dim {n}, backend {backend}, window {window_us}us");

    let ell_worker = ell.clone();
    let h_worker = h.clone();
    let svc = Service::start(
        ServiceConfig { batch_window: Duration::from_micros(window_us) },
        n,
        move || {
            if have_artifacts {
                let rt = Runtime::new(&default_artifacts_dir())?;
                let bound = rt.bind(&ell_worker, rt.load("spmv_b8_d24_n540.hlo.txt")?)?;
                Ok(Box::new(PjrtExecutor { bound }) as Box<dyn BatchExecutor>)
            } else {
                // Auto-tuned fallback: the tuning layer picks the
                // (scheme, C, σ, schedule) co-design AND arbitration
                // picks the executor backend for this matrix — the
                // example never names one. Each coalesced batch runs as
                // one fused dispatch. Basis caveat: this executor
                // interprets requests in the ORIGINAL basis, while the
                // PJRT artifact uses its ELL permuted basis — so the
                // printed checksum is NOT comparable across the two for
                // the same seed; it only guards against regressions
                // within one backend.
                let handle = SpmvHandle::builder(&h_worker)
                    .policy(TuningPolicy::Heuristic)
                    .threads(4)
                    .quick(true)
                    .build()?;
                eprintln!(
                    "worker: tuned fallback -> {} under {} on the {} backend",
                    handle.scheme().name(),
                    handle.schedule().name(),
                    handle.backend_name()
                );
                Ok(Box::new(Executor::from_handle(handle, 8)) as Box<dyn BatchExecutor>)
            }
        },
    )?;
    let mut router = Coordinator::new();
    router.register("holstein-hubbard", svc);

    // Open-loop load: fire all requests, then gather.
    let svc = router.route("holstein-hubbard")?;
    let mut rng = Rng::new(1234);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|_| {
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            svc.submit(x).unwrap()
        })
        .collect();
    let mut checksum = 0.0f64;
    for rx in rxs {
        let y = rx.recv().unwrap().map_err(|e| anyhow::anyhow!(e))?;
        checksum += y.iter().sum::<f64>();
    }
    let dt = t0.elapsed();

    let m = &svc.metrics;
    let mut t = Table::new("service metrics", &["metric", "value"]);
    t.row(vec!["backend".into(), backend.to_string()]);
    t.row(vec!["requests".into(), m.requests.load(Relaxed).to_string()]);
    t.row(vec!["batches".into(), m.batches.load(Relaxed).to_string()]);
    t.row(vec!["avg batch size".into(), f(m.avg_batch())]);
    t.row(vec!["avg latency (us)".into(), f(m.avg_latency_us())]);
    t.row(vec!["p_max latency (us)".into(), m.latency_us_max.load(Relaxed).to_string()]);
    t.row(vec!["errors".into(), m.errors.load(Relaxed).to_string()]);
    t.row(vec!["throughput (req/s)".into(), f(requests as f64 / dt.as_secs_f64())]);
    t.row(vec!["checksum".into(), format!("{checksum:.6e}")]);
    t.print();
    Ok(())
}
