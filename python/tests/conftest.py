import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

# Allow `import compile...` when pytest runs from python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
