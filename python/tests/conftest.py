import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

# Allow `import compile...` when pytest runs from python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# The test suite uses hypothesis for property sweeps. Offline images may
# lack it; fall back to a deterministic shim that runs each property over
# a fixed sample drawn from the declared strategies, so the suite still
# exercises the same code paths (with less input diversity).
try:
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import types

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    def _integers(min_value=0, max_value=2**31 - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def _given(**strategies):
        def deco(fn):
            # No functools.wraps: pytest must not see the strategy
            # parameters as fixture requests.
            def wrapper(*args, **kwargs):
                # @settings sits above @given and stamps _max_examples on
                # this wrapper after it is built; read it at call time.
                n = getattr(wrapper, "_max_examples", 20)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.hypothesis_shim = True
            return wrapper

        return deco

    _mod = types.ModuleType("hypothesis")
    _mod.given = _given
    _mod.settings = _settings
    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.integers = _integers
    _strategies.sampled_from = _sampled_from
    _mod.strategies = _strategies
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _strategies
