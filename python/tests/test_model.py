"""Layer-2 correctness: model entry points against references, and the
AOT pipeline (HLO text generation)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.aot import to_hlo_text
from compile.kernels.ref import ell_to_dense, lanczos_step_ref, random_ell


def test_spmv_batched_equals_loop():
    rng = np.random.default_rng(1)
    val, col = random_ell(rng, n=40, d=4)
    xs = rng.standard_normal((5, 40))
    batched = np.asarray(model.spmv_batched(jnp.asarray(val), jnp.asarray(col), jnp.asarray(xs)))
    for b in range(5):
        single = np.asarray(model.spmv(jnp.asarray(val), jnp.asarray(col), jnp.asarray(xs[b])))
        np.testing.assert_allclose(batched[b], single, rtol=1e-12)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_lanczos_step_matches_ref(seed):
    rng = np.random.default_rng(seed)
    val, col = random_ell(rng, n=30, d=4)
    v_prev = rng.standard_normal(30)
    v_cur = rng.standard_normal(30)
    v_cur /= np.linalg.norm(v_cur)
    beta = abs(rng.standard_normal())
    a1, b1, v1 = model.lanczos_step(
        jnp.asarray(val), jnp.asarray(col), jnp.asarray(v_prev), jnp.asarray(v_cur), beta
    )
    a2, b2, v2 = lanczos_step_ref(
        jnp.asarray(val), jnp.asarray(col), jnp.asarray(v_prev), jnp.asarray(v_cur), beta
    )
    np.testing.assert_allclose(a1, a2, rtol=1e-10)
    np.testing.assert_allclose(b1, b2, rtol=1e-10)
    np.testing.assert_allclose(v1, v2, rtol=1e-10)


def test_lanczos_iteration_converges_on_symmetric_matrix():
    # Full Lanczos driven through the model: lowest eigenvalue of a
    # symmetric ELL matrix must match numpy's eigh.
    rng = np.random.default_rng(7)
    n, d = 24, 6
    val, col = random_ell(rng, n=n, d=d)
    dense = ell_to_dense(val, col)
    dense = 0.5 * (dense + dense.T)
    # Re-pack symmetrized matrix into ELL by rows.
    valn = np.zeros((n, n))
    coln = np.zeros((n, n), dtype=np.int32)
    for i in range(n):
        for j in range(n):
            valn[j, i] = dense[i, j]
            coln[j, i] = j
    v = jnp.asarray(rng.standard_normal(n))
    v = v / jnp.linalg.norm(v)
    v_prev = jnp.zeros(n)
    beta = jnp.asarray(0.0)
    alphas, betas = [], []
    for _ in range(n):
        a, b, v_next = model.lanczos_step(
            jnp.asarray(valn), jnp.asarray(coln), v_prev, v, beta
        )
        alphas.append(float(a))
        betas.append(float(b))
        v_prev, v, beta = v, v_next, b
    t = np.diag(alphas) + np.diag(betas[:-1], 1) + np.diag(betas[:-1], -1)
    lo = np.linalg.eigvalsh(t)[0]
    want = np.linalg.eigvalsh(dense)[0]
    # No reorthogonalization here: modest tolerance.
    np.testing.assert_allclose(lo, want, rtol=1e-6, atol=1e-6)


def test_power_step_rayleigh():
    rng = np.random.default_rng(9)
    val, col = random_ell(rng, n=20, d=3)
    v = rng.standard_normal(20)
    v /= np.linalg.norm(v)
    v_next, rayleigh = model.power_step(
        jnp.asarray(val), jnp.asarray(col), jnp.asarray(v), 10.0
    )
    dense = ell_to_dense(val, col)
    np.testing.assert_allclose(float(rayleigh), v @ dense @ v, rtol=1e-10)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(v_next)), 1.0, rtol=1e-12)


def test_hlo_text_generation_contains_entry():
    val = jax.ShapeDtypeStruct((3, 8), jnp.float64)
    col = jax.ShapeDtypeStruct((3, 8), jnp.int32)
    x = jax.ShapeDtypeStruct((8,), jnp.float64)
    text = to_hlo_text(model.spmv, val, col, x)
    assert "ENTRY" in text
    assert "f64[8]" in text
