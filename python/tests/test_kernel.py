"""Layer-1 correctness: the Pallas ELL SpMV kernel against the pure-jnp
oracle, swept over shapes/dtypes/tilings with hypothesis."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ell_to_dense, random_ell, spmv_ell_ref
from compile.kernels.spmv_ell import spmv_ell, vmem_footprint_bytes


def run_both(val, col, x, **kw):
    got = np.asarray(spmv_ell(jnp.asarray(val), jnp.asarray(col), jnp.asarray(x), **kw))
    want = np.asarray(spmv_ell_ref(jnp.asarray(val), jnp.asarray(col), jnp.asarray(x)))
    return got, want


def test_identity_matrix():
    n = 16
    val = np.zeros((1, n))
    val[0] = 1.0
    col = np.arange(n, dtype=np.int32)[None, :]
    x = np.linspace(-1, 1, n)
    got, want = run_both(val, col, x)
    np.testing.assert_allclose(got, x)
    np.testing.assert_allclose(want, x)


def test_matches_dense_matvec():
    rng = np.random.default_rng(0)
    val, col = random_ell(rng, n=48, d=5)
    x = rng.standard_normal(48)
    dense = ell_to_dense(val, col)
    got, _ = run_both(val, col, x)
    np.testing.assert_allclose(got, dense @ x, rtol=1e-12, atol=1e-12)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=128),
    d=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    n_blk=st.sampled_from([4, 16, 64, 256]),
    d_blk=st.sampled_from([1, 2, 4, 8]),
)
def test_kernel_vs_ref_hypothesis(n, d, seed, n_blk, d_blk):
    rng = np.random.default_rng(seed)
    val, col = random_ell(rng, n=n, d=d)
    x = rng.standard_normal(n)
    got, want = run_both(val, col, x, n_blk=n_blk, d_blk=d_blk)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_kernel_f32(seed):
    rng = np.random.default_rng(seed)
    val, col = random_ell(rng, n=64, d=6, dtype=np.float32)
    x = rng.standard_normal(64).astype(np.float32)
    got, want = run_both(val, col, x)
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_empty_padding_rows_are_harmless():
    # Entire diagonals of padding must not change the result.
    rng = np.random.default_rng(3)
    val, col = random_ell(rng, n=32, d=3)
    pad_val = np.vstack([val, np.zeros((2, 32))])
    pad_col = np.vstack([col, np.zeros((2, 32), dtype=np.int32)])
    x = rng.standard_normal(32)
    a, _ = run_both(val, col, x)
    b, _ = run_both(pad_val, pad_col, x)
    np.testing.assert_allclose(a, b, rtol=1e-12)


def test_non_dividing_block_sizes_fall_back():
    rng = np.random.default_rng(4)
    val, col = random_ell(rng, n=37, d=5)  # primes: no tiling divides
    x = rng.standard_normal(37)
    got, want = run_both(val, col, x, n_blk=16, d_blk=4)
    np.testing.assert_allclose(got, want, rtol=1e-10)


def test_vmem_footprint_estimate():
    # The default demo config must fit comfortably in a v4 core's ~16 MiB
    # VMEM; a paper-scale config must be flagged as too big.
    small = vmem_footprint_bytes(n=540, d_blk=8, n_blk=256)
    assert small < 16 << 20
    huge = vmem_footprint_bytes(n=1_201_200, d_blk=24, n_blk=1_201_200)
    assert huge > 16 << 20


@pytest.mark.parametrize("n,d", [(1, 1), (2, 1), (3, 7)])
def test_degenerate_shapes(n, d):
    rng = np.random.default_rng(5)
    val, col = random_ell(rng, n=n, d=d)
    x = rng.standard_normal(n)
    got, want = run_both(val, col, x)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)
