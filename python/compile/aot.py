"""AOT pipeline: lower the Layer-2 entry points to HLO *text* artifacts
for the Rust PJRT runtime.

HLO text (not ``HloModuleProto.serialize``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact shapes are static (XLA requirement). Shapes are encoded in the
file names, e.g. ``spmv_b8_d24_n540.hlo.txt``; the Rust runtime parses
them back. f64 throughout (x64 enabled) to match the Rust solvers.

Usage: python -m compile.aot --out-dir ../artifacts [--n 540 --d 24 --batch 8]
"""

from __future__ import annotations

import argparse
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(fn, *args) -> str:
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float64):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts(out_dir: str, n: int, d: int, batch: int) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    f64 = jnp.float64
    i32 = jnp.int32
    val = spec((d, n), f64)
    col = spec((d, n), i32)
    x = spec((n,), f64)
    xs = spec((batch, n), f64)
    scalar = spec((), f64)

    jobs = [
        (f"spmv_d{d}_n{n}", model.spmv, (val, col, x)),
        (f"spmv_b{batch}_d{d}_n{n}", model.spmv_batched, (val, col, xs)),
        (f"lanczos_step_d{d}_n{n}", model.lanczos_step, (val, col, x, x, scalar)),
        (f"power_step_d{d}_n{n}", model.power_step, (val, col, x, scalar)),
    ]
    written = []
    for name, fn, args in jobs:
        text = to_hlo_text(fn, *args)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)
        written.append(path)
    return written


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--n", type=int, default=540, help="matrix dimension (HH tiny = 540)")
    p.add_argument("--d", type=int, default=24, help="max non-zeros per row (ELL depth)")
    p.add_argument("--batch", type=int, default=8, help="batched-SpMV batch size")
    args = p.parse_args()
    build_artifacts(args.out_dir, args.n, args.d, args.batch)


if __name__ == "__main__":
    main()
