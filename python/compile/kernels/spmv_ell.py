"""Layer-1 Pallas kernel: padded-JDS (ELL) sparse matrix-vector product.

Hardware adaptation (DESIGN.md §5): the paper's JDS format exists for
vector machines; a TPU is architecturally on that side of the paper's
CRS-vs-JDS dichotomy. The kernel therefore:

- tiles the ``(D, N)`` ``val``/``col`` planes into ``(D_BLK, N_BLK)``
  VMEM blocks streamed from HBM (the BlockSpec below *is* the paper's
  NBJDS cache-blocking, re-expressed as a VMEM schedule),
- keeps the ``N_BLK`` result tile resident across the diagonal loop
  (grid accumulation), exactly like NBJDS keeps the result block in
  cache (§2),
- keeps the input vector whole in VMEM (it is the gather target — the
  analogue of the paper's ``invec`` locality problem).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom calls; real-TPU performance is estimated analytically in
DESIGN.md / EXPERIMENTS.md §Perf instead of measured.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, val_ref, col_ref, y_ref):
    """One (D_BLK, N_BLK) tile: y_blk += sum_d val * x[col]."""
    d = pl.program_id(1)

    @pl.when(d == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[...]  # full input vector, VMEM resident
    val = val_ref[...]  # (D_BLK, N_BLK) tile
    col = col_ref[...]
    y_ref[...] += jnp.sum(val * x[col], axis=0)


@functools.partial(jax.jit, static_argnames=("n_blk", "d_blk"))
def spmv_ell(val, col, x, *, n_blk: int = 256, d_blk: int = 8):
    """y = A x for an ELL-packed matrix.

    Args:
      val: (D, N) non-zero values, 0.0-padded.
      col: (D, N) int32 column indices, 0-padded.
      x:   (N,) input vector.
      n_blk, d_blk: VMEM tile shape (clamped to the problem size).
    """
    d, n = val.shape
    assert col.shape == (d, n)
    assert x.shape == (n,)
    n_blk = min(n_blk, n)
    d_blk = min(d_blk, d)
    # Grid must tile exactly in interpret mode for simplicity: fall back
    # to one block when shapes do not divide.
    if n % n_blk != 0:
        n_blk = n
    if d % d_blk != 0:
        d_blk = d
    grid = (n // n_blk, d // d_blk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i, j: (0,)),  # x: whole vector
            pl.BlockSpec((d_blk, n_blk), lambda i, j: (j, i)),  # val tile
            pl.BlockSpec((d_blk, n_blk), lambda i, j: (j, i)),  # col tile
        ],
        out_specs=pl.BlockSpec((n_blk,), lambda i, j: (i,)),  # y tile (revisited over j)
        out_shape=jax.ShapeDtypeStruct((n,), val.dtype),
        interpret=True,
    )(x, val, col)


def vmem_footprint_bytes(n: int, d_blk: int, n_blk: int, dtype_bytes: int = 8) -> int:
    """Estimated VMEM footprint of one kernel instance: x + val tile +
    col tile + y tile (+ double buffering on the streamed tiles).

    Used by DESIGN.md §Perf to check tile choices against the ~16 MiB
    VMEM of a TPU v4 core without running on TPU hardware.
    """
    x = n * dtype_bytes
    tile = d_blk * n_blk * (dtype_bytes + 4)  # val + int32 col
    y = n_blk * dtype_bytes
    return x + 2 * tile + y  # x resident, tiles double-buffered
