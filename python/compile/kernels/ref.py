"""Pure-jnp reference oracles for the Pallas SpMV kernels.

The padded-JDS (ELL) layout stores a matrix whose permuted rows have at
most ``D`` non-zeros as two dense ``(D, N)`` arrays:

- ``val[d, i]``: the d-th stored non-zero of (permuted) row ``i``
  (0.0 padding),
- ``col[d, i]``: its (permuted) column index (0 padding; padding values
  are harmless because the corresponding ``val`` is 0).

This is the paper's JDS storage padded to rectangular — the layout its
vector-architecture lineage (§2) maps naturally onto a TPU's VPU lanes.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def spmv_ell_ref(val: jnp.ndarray, col: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[i] = sum_d val[d, i] * x[col[d, i]] — the ELL SpMV oracle."""
    assert val.shape == col.shape
    assert val.shape[1] == x.shape[0]
    return jnp.sum(val * x[col], axis=0)


def ell_to_dense(val: np.ndarray, col: np.ndarray, n_cols: int | None = None) -> np.ndarray:
    """Materialize an ELL matrix densely (tests only)."""
    d, n = val.shape
    n_cols = n_cols or n
    out = np.zeros((n, n_cols), dtype=val.dtype)
    for k in range(d):
        for i in range(n):
            out[i, col[k, i]] += val[k, i]
    return out


def random_ell(
    rng: np.random.Generator, n: int, d: int, fill: float = 0.7, dtype=np.float64
):
    """Random ELL arrays with ~fill fraction of populated slots (padding
    slots have val == 0 and col == 0, like the production packer)."""
    val = rng.standard_normal((d, n)).astype(dtype)
    col = rng.integers(0, n, size=(d, n)).astype(np.int32)
    mask = rng.random((d, n)) < fill
    val = np.where(mask, val, 0.0).astype(dtype)
    col = np.where(mask, col, 0).astype(np.int32)
    return val, col


def lanczos_step_ref(val, col, v_prev, v_cur, beta):
    """One Lanczos three-term recurrence step (reference).

    w = A v_cur - beta * v_prev
    alpha = <w, v_cur>
    w -= alpha * v_cur
    beta_new = ||w||
    v_next = w / beta_new
    """
    w = spmv_ell_ref(val, col, v_cur) - beta * v_prev
    alpha = jnp.dot(w, v_cur)
    w = w - alpha * v_cur
    beta_new = jnp.sqrt(jnp.dot(w, w))
    v_next = w / jnp.where(beta_new == 0.0, 1.0, beta_new)
    return alpha, beta_new, v_next
