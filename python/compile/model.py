"""Layer-2 JAX model: the compute graphs AOT-lowered for the Rust
runtime. Every entry point funnels its SpMV through the Layer-1 Pallas
kernel (`kernels.spmv_ell`), so the lowered HLO contains the kernel and
the Rust hot path never touches Python.

Entry points:

- ``spmv``: one matrix-vector product (the paper's core operation).
- ``spmv_batched``: a batch of input vectors against the same matrix —
  what the Rust coordinator's dynamic batcher dispatches.
- ``lanczos_step``: one three-term Lanczos recurrence step (the paper's
  motivating eigensolver, §1), fused around the kernel.
- ``power_step``: one shifted power-iteration step with Rayleigh
  quotient, used as a cross-check.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.spmv_ell import spmv_ell


def spmv(val, col, x):
    return spmv_ell(val, col, x)


def spmv_batched(val, col, xs):
    """xs: (B, N) batch of input vectors -> (B, N) results."""
    return jax.vmap(lambda x: spmv_ell(val, col, x))(xs)


def lanczos_step(val, col, v_prev, v_cur, beta):
    """One Lanczos step; returns (alpha, beta_new, v_next).

    w = A v_cur - beta v_prev;  alpha = <w, v_cur>;
    w -= alpha v_cur;           beta' = ||w||;  v' = w / beta'.
    """
    w = spmv_ell(val, col, v_cur) - beta * v_prev
    alpha = jnp.dot(w, v_cur)
    w = w - alpha * v_cur
    beta_new = jnp.sqrt(jnp.dot(w, w))
    v_next = w / jnp.where(beta_new == 0.0, 1.0, beta_new)
    return alpha, beta_new, v_next


def power_step(val, col, v, shift):
    """One power-iteration step on (shift I - A): returns (v_next,
    rayleigh) where rayleigh = <v, A v> of the *input* vector."""
    av = spmv_ell(val, col, v)
    rayleigh = jnp.dot(v, av)
    w = shift * v - av
    norm = jnp.sqrt(jnp.dot(w, w))
    v_next = w / jnp.where(norm == 0.0, 1.0, norm)
    return v_next, rayleigh
